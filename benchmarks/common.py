"""Shared benchmark scaffolding: the experimental problem of eq. (10) on a
LibSVM-shaped stand-in (offline container), byte accounting identical to
the paper's x-axis, and bits-to-accuracy extraction."""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.newton import newton_run
from repro.core.objectives import (batch_grad, batch_hess, global_value,
                                   lipschitz_constants)
from repro.data.synthetic import make_libsvm_like, make_synthetic

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def problem(name="a1a", lam=1e-3, seed=0):
    """Returns dict with oracles, x*, constants. 'a1a' etc. use Table 3
    shapes; 'synthetic' uses the Sec. A.14 generator."""
    key = jax.random.PRNGKey(seed)
    if name.startswith("synthetic"):
        _, alpha, beta = name.split(":")
        data = make_synthetic(key, float(alpha), float(beta), n=30, m=200,
                              d=100, lam=lam)
    else:
        data = make_libsvm_like(key, name, lam=lam)
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    val_fn = lambda x: global_value(x, data)
    d = data.a.shape[-1]
    xstar, _ = newton_run(jnp.zeros(d), grad_fn, hess_fn, 25)
    return dict(
        data=data, grad=grad_fn, hess=hess_fn, val=val_fn, xstar=xstar,
        fstar=float(val_fn(xstar)), d=d, n=data.a.shape[0],
        consts=lipschitz_constants(data),
    )


def gaps(prob, xs):
    return np.asarray(jax.vmap(prob["val"])(xs)) - prob["fstar"]


def bits_to_accuracy(gap_curve, bits_per_round, target=1e-9, init_bits=0.0):
    """Paper x-axis: communicated bits per node until gap <= target."""
    idx = np.nonzero(gap_curve <= target)[0]
    if len(idx) == 0:
        return float("inf")
    return float(init_bits + idx[0] * bits_per_round)


def rounds_to_accuracy(gap_curve, target=1e-9):
    idx = np.nonzero(gap_curve <= target)[0]
    return int(idx[0]) if len(idx) else -1


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return out, (time.time() - t0) * 1e6


def write_csv(name: str, header: list[str], rows: list[tuple]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
