"""Shared benchmark scaffolding: the experimental problem of eq. (10) on a
LibSVM-shaped stand-in (offline container), byte accounting identical to
the paper's x-axis, and bits-to-accuracy extraction."""

from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data.problems import make_problem as problem  # noqa: F401
from repro.engine import records

OUT_DIR = os.path.join(os.path.dirname(__file__), "out")


def gaps(prob, xs):
    return np.asarray(jax.vmap(prob["val"])(xs)) - prob["fstar"]


def bits_to_accuracy(gap_curve, bits_per_round, target=1e-9, init_bits=0.0):
    """Paper x-axis: communicated bits per node until gap <= target.
    Per-round-rate variant of ``repro.engine.records.bits_to_accuracy``."""
    bits = init_bits + bits_per_round * np.arange(len(gap_curve))
    return records.bits_to_accuracy(gap_curve, bits, target)


def rounds_to_accuracy(gap_curve, target=1e-9):
    return records.rounds_to_accuracy(gap_curve, target)


def timed(fn, *args, **kw):
    t0 = time.time()
    out = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(out)[0])
    return out, (time.time() - t0) * 1e6


def write_csv(name: str, header: list[str], rows: list[tuple]):
    os.makedirs(OUT_DIR, exist_ok=True)
    path = os.path.join(OUT_DIR, f"{name}.csv")
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path
