"""Benchmark harness — one function per paper figure/table.

FedNL-family cells are declarative ``ExperimentSpec`` grids executed by
``repro.engine.Sweep`` (one vmapped+scanned jitted program per cell);
first-order and inexact-Newton baselines keep their bespoke drivers.
Prints ``name,us_per_call,derived`` CSV to stdout (derived = the claim
check for that artifact) and writes full curves to benchmarks/out/*.csv
with a ``us_per_round`` column per cell.

  fig2_local        FedNL & N0 vs GD/DIANA/ADIANA/DINGO, bits to 1e-6
  fig2_global       FedNL-LS/N0-LS/FedNL-CR vs first-order, from far
  fig2_nl1          FedNL (Rank-1/Top-K/PowerSGD) vs NL1
  fig3_compression  Rank-R / Top-K / PowerSGD level sweep
  fig4_options      Option 1 vs Option 2
  fig6_update_rules alpha rules (Top-K a=1, a=1-sqrt(1-d), Rand-K 1/(w+1))
  fig7_bc           FedNL-BC compression-level sweep + vs DORE
  fig9_pp           FedNL-PP tau sweep + vs Artemis
  fig14_heterogeneity  synthetic(alpha, beta) sweep
  table2_rates      Thm 3.6 / NS / N0 rate checks
  codec_roundtrip   bitstream codec encode/decode per payload family:
                    bytes vs entropy estimate, fp32 bit-exact pin
  autotune          kernel autotuner: measured winners vs untuned defaults
                    (exact numerics + not-slower pins, cache JSON
                    round-trip; honors $REPRO_TUNING_CACHE)
  server_aggregate  payload-space aggregate vs decompress-then-mean (n x d,
                    incl. the tiled-accumulator large-d sweep)
  precond_step      fednl_precond payload-op path vs dense-mask path
  engine_vmap       multi-seed vmap speedup vs serial per-seed loops
  roofline          (arch x shape) table from the dry-run JSONL

Run: PYTHONPATH=src python -m benchmarks.run [--only NAME] [--fast]
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

# The paper's separation between Newton-type and first-order methods shows
# at deep accuracy (superlinear regime); run the convex benchmarks in f64.
jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from benchmarks.common import bits_to_accuracy, gaps, problem, write_csv
from repro.core import FedNL, RandK, RandomDithering, RankR, TopK
from repro.core.baselines import (
    NL1,
    Adiana,
    Artemis,
    Diana,
    Dingo,
    Dore,
    gd_ls_run,
    gd_run,
)
from repro.core.compressors import FLOAT_BITS
from repro.engine import (
    ExperimentSpec,
    Sweep,
    bits_to_accuracy as bits_at,
    rounds_to_accuracy as rounds_at,
)

RESULTS = []
TARGET = 1e-12


def report(name, us_per_call, derived):
    RESULTS.append((name, us_per_call, derived))
    print(f"{name},{us_per_call:.1f},{derived}")
    sys.stdout.flush()


def _near_x0(prob, scale=0.05, seed=1):
    return prob["xstar"] + scale * jax.random.normal(
        jax.random.PRNGKey(seed), (prob["d"],))


def _run(alg_run, *args, **kw):
    t0 = time.time()
    out = alg_run(*args, **kw)
    jax.block_until_ready(out[1])
    return out, (time.time() - t0) * 1e6


def _sweep(prob, specs, x0):
    """Run an ExperimentSpec grid; returns (SweepResult, total wall us)."""
    res = Sweep(specs).run(prob, x0=x0)
    us = sum(c.us_per_round * c.spec.num_rounds for c in res.cells)
    return res, us


# ---------------------------------------------------------------------------


def fig2_local(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    rounds = 60 if fast else 150

    res, us = _sweep(prob, [
        ExperimentSpec("fednl", "rankr", 1, params=dict(option=1, mu=1e-3),
                       num_rounds=25, name="FedNL-Rank1"),
        ExperimentSpec("n0", num_rounds=40, name="N0"),
    ], x0)
    cf, c0 = res.cells
    b_fednl = bits_at(cf.gaps[0], cf.bits, TARGET)
    b_n0 = bits_at(c0.gaps[0], c0.bits, TARGET)
    rows = [("FedNL-Rank1", float(b), float(g))
            for b, g in zip(cf.bits, cf.gaps[0])]

    (_, xs_gd), _ = _run(gd_run, x0, prob["grad"], 1.0 / prob["consts"]["L"],
                         rounds * 40)
    b_gd = bits_to_accuracy(gaps(prob, xs_gd), d * FLOAT_BITS, TARGET)

    rd = RandomDithering(s=int(d ** 0.5))
    om = rd.spec((d,)).omega
    diana = Diana(prob["grad"], rd, prob["consts"]["L"], n, om)
    (_, xs_di), _ = _run(diana.run, x0, n, rounds * 10)
    b_diana = bits_to_accuracy(gaps(prob, xs_di), diana.bits_per_round(d),
                               TARGET)

    adiana = Adiana(prob["grad"], rd, prob["consts"]["L"], 1e-3, n, om)
    (_, xs_ad), _ = _run(adiana.run, x0, n, rounds * 10)
    b_adiana = bits_to_accuracy(gaps(prob, xs_ad), adiana.bits_per_round(d),
                                TARGET)

    dingo = Dingo(prob["val"], prob["grad"], prob["hess"])
    (_, xs_dg), _ = _run(dingo.run, x0, 40)
    b_dingo = bits_to_accuracy(gaps(prob, xs_dg), dingo.bits_per_round(d),
                               TARGET)

    write_csv("fig2_local", ["method", "bits", "gap"], rows)
    best_fo = min(b_gd, b_diana, b_adiana)
    claim = (b_fednl < best_fo) and (b_n0 < best_fo) and (b_fednl < b_dingo)
    report("fig2_local", us,
           f"bits(FedNL)={b_fednl:.2e}|N0={b_n0:.2e}|GD={b_gd:.2e}|"
           f"DIANA={b_diana:.2e}|ADIANA={b_adiana:.2e}|DINGO={b_dingo:.2e}|"
           f"claim_fednl_beats_all={claim}")


def fig2_global(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    x0 = jnp.ones(d) * 2.0
    rounds = 40 if fast else 80

    res, us = _sweep(prob, [
        ExperimentSpec("fednl-ls", "rankr", 1, params=dict(mu=1e-3),
                       num_rounds=rounds, name="FedNL-LS"),
        ExperimentSpec("n0-ls", params=dict(mu=1e-3), num_rounds=rounds,
                       name="N0-LS"),
        ExperimentSpec("fednl-cr", "rankr", 1,
                       params=dict(l_star=prob["consts"]["L_star"]),
                       num_rounds=rounds * 4, name="FedNL-CR"),
    ], x0)
    b_ls = bits_at(res.cell("FedNL-LS").gaps[0], res.cell("FedNL-LS").bits,
                   TARGET)
    b_n0ls = bits_at(res.cell("N0-LS").gaps[0], res.cell("N0-LS").bits,
                     TARGET)
    b_cr = bits_at(res.cell("FedNL-CR").gaps[0], res.cell("FedNL-CR").bits,
                   TARGET)

    (_, xs_gd), _ = _run(gd_run, x0, prob["grad"], 1.0 / prob["consts"]["L"],
                         rounds * 20)
    b_gd = bits_to_accuracy(gaps(prob, xs_gd), d * FLOAT_BITS, TARGET)
    (_, xs_gls), _ = _run(gd_ls_run, x0, prob["val"], prob["grad"], rounds * 20)
    b_gdls = bits_to_accuracy(gaps(prob, xs_gls), d * FLOAT_BITS, TARGET)

    rd = RandomDithering(s=int(d ** 0.5))
    om = rd.spec((d,)).omega
    diana = Diana(prob["grad"], rd, prob["consts"]["L"], n, om)
    (_, xs_di), _ = _run(diana.run, x0, n, rounds * 20)
    b_diana = bits_to_accuracy(gaps(prob, xs_di), diana.bits_per_round(d),
                               TARGET)

    claim = (b_ls < min(b_gd, b_gdls, b_diana)) and \
        (b_n0ls < min(b_gd, b_gdls)) and (b_cr < min(b_gd, b_gdls))
    report("fig2_global", us,
           f"bits(FedNL-LS)={b_ls:.2e}|N0-LS={b_n0ls:.2e}|FedNL-CR={b_cr:.2e}|"
           f"GD={b_gd:.2e}|GD-LS={b_gdls:.2e}|DIANA={b_diana:.2e}|"
           f"claim_ls_beats_first_order={claim}")


def fig2_nl1(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    # start far enough that the Hessian-learning transient matters (NL1
    # must re-learn m coefficients per silo at K=1/round)
    x0 = _near_x0(prob, scale=0.3)
    res, us = _sweep(prob, [
        ExperimentSpec("fednl", "rankr", 1, params=dict(option=1, mu=1e-3),
                       num_rounds=40, name="Rank1"),
        ExperimentSpec("fednl", "topk", d, params=dict(option=1, mu=1e-3),
                       num_rounds=40, name=f"Top{d}"),
        ExperimentSpec("fednl", "powersgd", 1, params=dict(option=1, mu=1e-3),
                       num_rounds=40, name="PowerSGD1"),
    ], x0)
    bits = {c.spec.label: bits_at(c.gaps[0], c.bits, TARGET)
            for c in res.cells}
    nl1 = NL1(prob["data"], k=1)
    (_, xs), _ = _run(nl1.run, x0, 400 if not fast else 150)
    bits["NL1-Rand1"] = bits_to_accuracy(gaps(prob, xs),
                                         nl1.bits_per_round(d), TARGET,
                                         d * (d + 1) // 2 * FLOAT_BITS)
    fednl_best = min(v for k, v in bits.items() if k != "NL1-Rand1")
    claim = (fednl_best < bits["NL1-Rand1"]
             and bits["Rank1"] < bits["NL1-Rand1"])
    report("fig2_nl1", us,
           "|".join(f"{k}={v:.2e}" for k, v in bits.items())
           + f"|claim_fednl_beats_nl1={claim}")


def fig3_compression(fast=False):
    prob = problem("phishing")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    grid = [("rankr", [1, 2, 4]), ("topk", [d, 4 * d, 16 * d]),
            ("powersgd", [1, 2, 4])]
    specs = [ExperimentSpec("fednl", fam, lvl,
                            params=dict(option=1, mu=1e-3), num_rounds=40)
             for fam, levels in grid for lvl in levels]
    res, us = _sweep(prob, specs, x0)
    rows, verdicts = [], []
    by = {(c.spec.compressor, c.spec.level): c for c in res.cells}
    for fam, levels in grid:
        bl = {lvl: bits_at(by[(fam, lvl)].gaps[0], by[(fam, lvl)].bits,
                           TARGET) for lvl in levels}
        rows += [(fam, lvl, bl[lvl], by[(fam, lvl)].us_per_round)
                 for lvl in levels]
        verdicts.append(bl[levels[0]] <= bl[levels[-1]])
    write_csv("fig3_compression", ["family", "level", "bits", "us_per_round"],
              rows)
    report("fig3_compression", us,
           f"rows={len(rows)}|claim_smaller_level_better={all(verdicts)}")


def fig4_options(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    res, us = _sweep(prob, [
        ExperimentSpec("fednl", "rankr", 1, params=dict(option=opt, mu=1e-3),
                       num_rounds=120, name=f"opt{opt}")
        for opt in (1, 2)
    ], x0)
    out = {opt: bits_at(res.cell(f"opt{opt}").gaps[0],
                        res.cell(f"opt{opt}").bits, TARGET)
           for opt in (1, 2)}
    report("fig4_options", us,
           f"opt1={out[1]:.2e}|opt2={out[2]:.2e}|"
           f"claim_opt1_not_worse={out[1] <= out[2] * 1.01}")


def fig6_update_rules(fast=False):
    prob = problem("phishing")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob, scale=0.3)
    k = d // 2
    delta = TopK(k=k).spec((d, d)).delta
    omega = RandK(k=k).spec((d, d)).omega
    res, us = _sweep(prob, [
        ExperimentSpec("fednl", "topk", k,
                       params=dict(alpha=1.0, option=1, mu=1e-3),
                       num_rounds=150, name="topk_a1"),
        ExperimentSpec("fednl", "topk", k,
                       params=dict(alpha=1.0 - (1.0 - delta) ** 0.5,
                                   option=1, mu=1e-3),
                       num_rounds=150, name="topk_contract"),
        ExperimentSpec("fednl", "randk", k,
                       params=dict(alpha=1.0 / (1.0 + omega),
                                   option=1, mu=1e-3),
                       num_rounds=150, name="randk_unbiased"),
    ], x0)
    rounds_out = {c.spec.label: rounds_at(c.gaps[0], TARGET)
                  for c in res.cells}
    ok = {k_: (v if v >= 0 else 10**9) for k_, v in rounds_out.items()}
    claim = ok["topk_a1"] <= min(ok.values())
    report("fig6_update_rules", us,
           "|".join(f"{k_}={v}" for k_, v in rounds_out.items())
           + f"|claim_topk_a1_best={claim}")


def fig7_bc(fast=False):
    prob = problem("phishing")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    ps = [0.9, 0.6] if fast else [1.0, 0.9, 0.6, 0.5]
    res, us = _sweep(prob, [
        ExperimentSpec("fednl-bc", "topk", max(1, int(p * d)),
                       params=dict(model_compressor=("topk",
                                                     max(1, int(p * d))),
                                   p=p, option=1, mu=1e-3),
                       num_rounds=600, name=f"p={p}")
        for p in ps
    ], x0)
    bits = {c.spec.label: bits_at(c.gaps[0], c.bits, TARGET)
            for c in res.cells}
    rd = RandomDithering(s=int(d ** 0.5))
    om = rd.spec((d,)).omega
    dore = Dore(prob["grad"], rd, rd, prob["consts"]["L"], n, om, om)
    (_, xs), _ = _run(dore.run, x0, n, 3000 if not fast else 800)
    up, down = dore.bits_per_round(d)
    bits["DORE"] = bits_to_accuracy(gaps(prob, xs), up + down, TARGET)
    best_bc = min(v for k, v in bits.items() if k != "DORE")
    report("fig7_bc", us,
           "|".join(f"{k}={v:.2e}" for k, v in bits.items())
           + f"|claim_bc_beats_dore={best_bc < bits['DORE']}")


def fig9_pp(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    taus = [max(1, int(0.2 * n)), max(1, int(0.5 * n)), n]
    res, us = _sweep(prob, [
        ExperimentSpec("fednl-pp", "rankr", 1, params=dict(tau=tau),
                       num_rounds=200, name=f"tau={tau}")
        for tau in taus
    ], x0)
    rounds_out = {tau: rounds_at(res.cell(f"tau={tau}").gaps[0], TARGET)
                  for tau in taus}
    mono = rounds_out[taus[0]] >= rounds_out[taus[-1]] >= 0

    rd = RandomDithering(s=int(d ** 0.5))
    om = rd.spec((d,)).omega
    art = Artemis(prob["grad"], rd, prob["consts"]["L"], n, om,
                  tau=max(1, int(0.5 * n)))
    (_, xs), _ = _run(art.run, x0, n, 3000 if not fast else 800)
    pp_cell = res.cell(f"tau={max(1, int(0.5 * n))}")
    b_art = bits_to_accuracy(gaps(prob, xs), art.bits_per_round(d), TARGET)
    b_pp = bits_at(pp_cell.gaps[0], pp_cell.bits, TARGET)
    report("fig9_pp", us,
           "|".join(f"tau={k}:rounds={v}" for k, v in rounds_out.items())
           + f"|mono_in_tau={mono}|bits_pp={b_pp:.2e}|bits_artemis={b_art:.2e}"
           f"|claim_pp_beats_artemis={b_pp < b_art}")


def fig14_heterogeneity(fast=False):
    us = 0.0
    out = {}
    for tag, ab in [("iid", (0.0, 0.0)), ("mid", (0.5, 0.5)),
                    ("high", (1.0, 1.0))]:
        prob = problem(f"synthetic:{ab[0]}:{ab[1]}")
        d, n = prob["d"], prob["n"]
        x0 = _near_x0(prob)
        res, u = _sweep(prob, [
            ExperimentSpec("fednl", "rankr", 1, params=dict(option=2),
                           num_rounds=30, name="FedNL"),
        ], x0)
        us += u
        cell = res.cells[0]
        b_f = bits_at(cell.gaps[0], cell.bits, TARGET)
        (_, xs_gd), _ = _run(gd_run, x0, prob["grad"],
                             1.0 / prob["consts"]["L"], 1500 if fast else 4000)
        b_g = bits_to_accuracy(gaps(prob, xs_gd), d * FLOAT_BITS, TARGET)
        out[tag] = (b_f, b_g)
    # FedNL stays put; GD degrades (or at least never closes the gap)
    claim = all(v[0] < v[1] for v in out.values())
    report("fig14_heterogeneity", us,
           "|".join(f"{k}:fednl={v[0]:.2e},gd={v[1]:.2e}"
                    for k, v in out.items())
           + f"|claim_fednl_wins_all_levels={claim}")


def table2_rates(fast=False):
    prob = problem("a1a")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob, scale=0.02)
    checks = {}
    alg = FedNL(prob["grad"], prob["hess"], RankR(1), option=1, mu=1e-3)
    (_, xs), us = _run(alg.run, x0, n, 16)
    r = np.asarray(jnp.sum((xs - prob["xstar"]) ** 2, axis=-1))
    ks = [k for k in range(1, 12) if r[k] > 1e-14]
    checks["fednl_linear_eq6"] = all(r[k] <= r[0] / 2**k * 8 for k in ks)
    # superlinear: the rate factor is (1-A)^k with A = delta/4; use a
    # high-delta compressor (Top-50% => A = 1/8) so the decay of the
    # per-round ratio is measurable before machine precision.
    alg_s = FedNL(prob["grad"], prob["hess"], TopK(k=d * d // 2), option=1,
                  mu=1e-3)
    x0_s = _near_x0(prob, scale=0.12, seed=5)  # inside the local basin
    (_, xs_s), _ = _run(alg_s.run, x0_s, n, 16)
    rs = np.asarray(jnp.sum((xs_s - prob["xstar"]) ** 2, axis=-1))
    ratios = [rs[k + 1] / rs[k] for k in range(10) if rs[k] > 1e-24]
    checks["fednl_superlinear"] = (len(ratios) >= 3
                                   and ratios[-1] < ratios[0] * 0.5)

    from repro.core.newton import fixed_hessian_run

    hstar = jnp.mean(prob["hess"](prob["xstar"]), axis=0)
    (_, xs_ns), _ = _run(fixed_hessian_run, x0, hstar, prob["grad"], 6)
    rr = np.linalg.norm(np.asarray(xs_ns) - np.asarray(prob["xstar"]), axis=-1)
    c = prob["consts"]["L_star"] / (2 * 1e-3)
    checks["ns_quadratic"] = all(
        rr[k + 1] <= 20 * c * rr[k] ** 2 + 1e-14
        for k in range(3) if rr[k] > 1e-9)

    h0 = jnp.mean(prob["hess"](x0), axis=0)
    (_, xs_n0), _ = _run(fixed_hessian_run, x0, h0, prob["grad"], 12)
    r0 = np.sum((np.asarray(xs_n0) - np.asarray(prob["xstar"])) ** 2, -1)
    checks["n0_linear"] = r0[10] <= r0[0] / 2**10 * 32
    report("table2_rates", us,
           "|".join(f"{k}={v}" for k, v in checks.items())
           + f"|all={all(checks.values())}")


def payload_roundtrip(fast=False):
    """Compressor wire-format micro-benchmark: payload compress /
    decompress round-trip vs INDEPENDENT seed-era dense oracles on a
    (d, d) Hessian diff (so a lossy codec actually fails the claim),
    measured-vs-analytic bits, and the Pallas block_topk payload op vs
    the jnp codec."""
    from repro.core import BlockTopK, payload_bits
    from repro.kernels.block_topk import block_topk, block_topk_payload, \
        payload_to_dense

    d = 128 if fast else 256
    m = jax.random.normal(jax.random.PRNGKey(0), (d, d))
    m = 0.5 * (m + m.T)
    key = jax.random.PRNGKey(1)

    # independent dense oracles (seed-era formulas / the Pallas kernel
    # path), deliberately NOT comp.__call__ — that is the round-trip
    def topk_oracle(x, _):
        flat = x.reshape(-1)
        _, idx = jax.lax.top_k(jnp.abs(flat), 4 * d)
        return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(x.shape)

    def rankr_oracle(x, _):
        lam, q = jnp.linalg.eigh(0.5 * (x + x.T))
        _, idx = jax.lax.top_k(jnp.abs(lam), 4)
        return (q[:, idx] * lam[idx]) @ q[:, idx].T

    def randk_oracle(x, k):
        flat = x.reshape(-1)
        n = flat.shape[0]
        idx = jax.random.choice(k, n, (4 * d,), replace=False)
        mask = jnp.zeros((n,), x.dtype).at[idx].set(1.0)
        return (flat * mask * (n / (4 * d))).reshape(x.shape)

    cases = {
        "topk": (TopK(k=4 * d), topk_oracle),
        "blocktopk": (BlockTopK(k_per_block=64, block=128),
                      lambda x, _: block_topk(x, k=64, block=128)),
        "rankr": (RankR(4), rankr_oracle),
        "randk": (RandK(k=4 * d), randk_oracle),
    }

    def bench(fn, *args, reps=20):
        out = jax.block_until_ready(fn(*args))  # compile
        t0 = time.time()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return out, (time.time() - t0) * 1e6 / reps

    us_total, fields, ok_bits, ok_ident = 0.0, [], True, True
    for name, (comp, oracle) in cases.items():
        dense_fn = jax.jit(oracle)
        rt_fn = jax.jit(lambda x, k, c=comp: c.decompress(
            c.compress(x, k), x.shape))
        out_dense, us_dense = bench(dense_fn, m, key)
        out_rt, us_rt = bench(rt_fn, m, key)
        ok_ident &= bool(jnp.all(out_dense == out_rt))
        measured = payload_bits(comp, (d, d))
        analytic = comp.bits((d, d))
        ok_bits &= (measured == analytic)
        us_total += us_rt
        # ';' not ',' inside the derived field — bench stdout is 3-col CSV
        fields.append(f"{name}:us_dense={us_dense:.0f};us_rt={us_rt:.0f};"
                      f"bits={measured}")

    # Pallas payload op agrees with the jnp codec's decompressed matrix
    # (kernel body forced — the off-TPU dispatch is the jnp oracle)
    bt = cases["blocktopk"][0]
    vals, idx = block_topk_payload(m, k=64, block=128, use_pallas=True,
                                   interpret=True)
    kernel_dense = payload_to_dense(vals, idx, m.shape, block=128)
    codec_dense = bt.decompress(bt.compress(m), m.shape)
    ok_kernel = bool(jnp.all(kernel_dense == codec_dense))

    report("payload_roundtrip", us_total,
           "|".join(fields)
           + f"|claim_roundtrip_bit_identical={ok_ident}"
           f"|claim_measured_eq_analytic={ok_bits}"
           f"|claim_pallas_payload_matches_codec={ok_kernel}")


def codec_roundtrip(fast=False):
    """Bitstream codec micro-benchmark: for one payload per family,
    host-side encode/decode throughput, actual wire bytes vs the
    ``bits_entropy`` accounting estimate, and the round-trip pins. The
    fp32 ``value_format="raw"`` path must be BIT-exact against
    ``canonical(payload)`` for every family, and the Golomb–Rice index
    coder must land within 1.1x of the entropy estimate for TopK (the
    estimate is a lower-bound-style count; the codec pays real container
    and rice-parameter overhead)."""
    from repro.core import BlockTopK, NaturalSparsification
    from repro.wire import canonical, decode, encode, wire_cost

    d = 32 if fast else 128
    key = jax.random.PRNGKey(1)
    m32 = jax.random.normal(jax.random.PRNGKey(0), (d, d), jnp.float32)

    cases = {
        "topk": TopK(k=4 * d),
        "blocktopk": BlockTopK(k_per_block=8, block=16),
        "rankr": RankR(4),
        "natural": NaturalSparsification(p=0.25),
        "dithering": RandomDithering(s=8),
    }

    def bit_equal(a, b):
        la = jax.tree_util.tree_leaves(a)
        lb = jax.tree_util.tree_leaves(b)
        if len(la) != len(lb):
            return False
        for x, y in zip(la, lb):
            x, y = np.asarray(x), np.asarray(y)
            if x.dtype != y.dtype or x.shape != y.shape:
                return False
            if x.tobytes() != y.tobytes():  # bitwise: -0.0 != +0.0 here
                return False
        return True

    def bench_host(fn, *args, reps=10):
        out = fn(*args)  # warm (device->host pull, rice param search)
        t0 = time.time()
        for _ in range(reps):
            out = fn(*args)
        return out, (time.time() - t0) * 1e6 / reps

    rows, fields = [], []
    ok_exact, ok_topk_entropy, us_total = True, True, 0.0
    for name, comp in cases.items():
        payload = jax.block_until_ready(comp.compress(m32, key))
        buf, us_enc = bench_host(encode, payload)
        dec, us_dec = bench_host(decode, buf, (d, d))
        exact = bit_equal(dec, canonical(payload))
        ok_exact &= exact
        rep = wire_cost(comp, (d, d), dtype=jnp.float32)
        if name == "topk":
            ok_topk_entropy = rep.encoded_bits <= 1.1 * rep.entropy_bits
        us_total += us_enc + us_dec
        rows.append((name, len(buf), rep.raw_bits, rep.entropy_bits,
                     us_enc, us_dec))
        fields.append(f"{name}:bytes={len(buf)};entropy={rep.entropy_bits};"
                      f"us_enc={us_enc:.0f};us_dec={us_dec:.0f}")

    write_csv("codec_roundtrip",
              ["family", "encoded_bytes", "raw_bits", "entropy_bits",
               "us_encode", "us_decode"], rows)
    report("codec_roundtrip", us_total,
           "|".join(fields)
           + f"|claim_fp32_roundtrip_exact={ok_exact}"
           f"|claim_topk_encoded_le_1p1x_entropy={ok_topk_entropy}")


def autotune(fast=False):
    """Kernel autotuner micro-benchmark (the CI smoke case): run the
    measured tuner for every tunable op on small operands, then time
    the cache-driven dispatch against the untuned default config.
    Claims: (a) tuned output == default output on tie-free operands
    (exact for the order-free ops, f32-tolerance for the hess_update
    error norm whose tile-sum order depends on block), (b) tuned is not
    slower than default up to timer noise — the default IS a candidate,
    so the measured winner can only match or beat it, (c) the winner
    cache round-trips through its JSON persistence unchanged. With
    $REPRO_TUNING_CACHE set (CI pins benchmarks/tuning_cache_ci.json)
    pinned entries are used as-is and only missing keys are tuned; the
    active cache is saved to benchmarks/out/tuning_cache.json either
    way — copy it over the committed pin to refresh it. The warmed
    cache stays active so the tuned columns in ``server_aggregate`` and
    ``precond_step`` (which run after this bench) dispatch through it."""
    from repro.kernels import tuning
    from repro.kernels.hess_update import hess_update
    from repro.kernels.scatter_accum import scatter_accumulate

    interp = jax.default_backend() != "tpu"
    pin = os.environ.get(tuning.CACHE_ENV)
    pinned = bool(pin and os.path.exists(pin))
    reps = 2 if fast else 3
    rows = []

    # -- scatter_accumulate: the headline op ------------------------------
    # unique flat indices -> every output cell receives at most one
    # contribution, so any (tile, chunk) config must be BITWISE equal
    d, k, n = 256, 128, 2
    vals = jax.random.normal(jax.random.PRNGKey(0), (n, k))
    idx = jax.random.permutation(
        jax.random.PRNGKey(1), d * d)[:n * k].reshape(n, k).astype(jnp.int32)

    def run_scatter():
        return scatter_accumulate(vals, idx, (d, d), use_pallas=True,
                                  interpret=interp)

    # default-config dispatch: pin an EMPTY cache so lookup misses
    tuning.set_cache(tuning.TuningCache())
    out_default = jax.block_until_ready(run_scatter())
    us_default = tuning.time_us(run_scatter, reps=reps)

    # tuned dispatch: restore the ambient cache (the CI pin when set),
    # tune any missing key, and re-dispatch through the plain wrapper
    tuning.set_cache(None)
    cfg_s = tuning.lookup("scatter_accumulate", shape=(d, d), k=k, n=n,
                          dtype=vals.dtype)
    if cfg_s is None:
        cfg_s = tuning.autotune_scatter_accumulate(
            vals, idx, (d, d), use_pallas=True, interpret=interp, reps=reps)
    out_tuned = jax.block_until_ready(run_scatter())
    us_tuned = tuning.time_us(run_scatter, reps=reps)
    err_s = float(jnp.max(jnp.abs(out_tuned - out_default)))
    ok_exact = bool(jnp.array_equal(out_tuned, out_default))
    # 1.25x + 100us absolute slack: these are ~ms interpret kernels and
    # CI runner timers are noisy; the winner was MEASURED no slower
    ok_speed = us_tuned <= 1.25 * us_default + 100.0
    rows.append(("scatter_accumulate", f"d{d};k{k};n{n}",
                 f"tile={cfg_s.tile};chunk={cfg_s.chunk}",
                 us_default, us_tuned, err_s))

    # -- hess_update: non-multiple-of-block shape (edge-tile path) --------
    hm = jax.random.normal(jax.random.PRNGKey(2), (300, 123), jnp.float32)
    dm = jax.random.normal(jax.random.PRNGKey(3), (300, 123), jnp.float32)
    sm = jax.random.normal(jax.random.PRNGKey(4), (300, 123), jnp.float32)
    h_def, e_def = jax.block_until_ready(
        hess_update(hm, dm, sm, 0.5, block=128, interpret=interp))
    us_h_def = tuning.time_us(
        lambda: hess_update(hm, dm, sm, 0.5, block=128, interpret=interp),
        reps=reps)
    cfg_h = tuning.lookup("hess_update", shape=hm.shape, dtype=hm.dtype)
    if cfg_h is None:
        cfg_h = tuning.autotune_hess_update(hm, dm, sm, 0.5,
                                            interpret=interp, reps=reps)
    h_tun, e_tun = jax.block_until_ready(
        hess_update(hm, dm, sm, 0.5, interpret=interp))
    us_h_tun = tuning.time_us(
        lambda: hess_update(hm, dm, sm, 0.5, interpret=interp), reps=reps)
    # H' is elementwise (block-independent -> exact); the fused error
    # norm sums per-tile partials, so its order depends on block
    ok_exact &= bool(jnp.array_equal(h_tun, h_def))
    err_e = abs(float(e_tun) - float(e_def)) / max(float(e_def), 1e-30)
    ok_exact &= err_e <= 1e-6
    ok_speed &= us_h_tun <= 1.25 * us_h_def + 100.0
    rows.append(("hess_update", "d300x123", f"block={cfg_h.block}",
                 us_h_def, us_h_tun, err_e))

    # -- diff_topk_payload: kernel-vs-oracle dispatch ---------------------
    from repro.kernels.block_topk import diff_topk_payload

    a = jax.random.normal(jax.random.PRNGKey(5), (d, d))
    b = jax.random.normal(jax.random.PRNGKey(6), (d, d))
    v_def, i_def, q_def = jax.block_until_ready(
        diff_topk_payload(a, b, k=64, block=128, use_pallas=not interp,
                          interpret=interp))
    cfg_t = tuning.lookup("diff_topk_payload", shape=a.shape, k=64, n=128,
                          dtype=a.dtype)
    if cfg_t is None:
        cfg_t = tuning.autotune_diff_topk_payload(a, b, k=64, block=128,
                                                  interpret=interp,
                                                  reps=reps)
    v_tun, i_tun, q_tun = jax.block_until_ready(
        diff_topk_payload(a, b, k=64, block=128, interpret=interp))
    ok_exact &= bool(jnp.array_equal(v_tun, v_def)
                     and jnp.array_equal(i_tun, i_def))
    ok_exact &= abs(float(q_tun) - float(q_def)) <= 1e-9 * float(q_def)
    rows.append(("diff_topk_payload", f"d{d};k64;b128",
                 f"use_pallas={cfg_t.use_pallas}", 0.0, 0.0, 0.0))

    if not fast:
        # pin-generation keys: the bench-smoke shapes the tuned columns
        # in server_aggregate (f64 TopK payloads at d=2048) and
        # precond_step (f32 block-diff at d=1024) dispatch through
        comp_vals = jax.random.normal(jax.random.PRNGKey(7), (2, 256))
        comp_idx = jax.random.permutation(
            jax.random.PRNGKey(8),
            2048 * 2048)[:512].reshape(2, 256).astype(jnp.int32)
        if tuning.lookup("scatter_accumulate", shape=(2048, 2048), k=256,
                         n=2, dtype=comp_vals.dtype) is None:
            tuning.autotune_scatter_accumulate(
                comp_vals, comp_idx, (2048, 2048), use_pallas=True,
                interpret=interp, max_measured=3, reps=1)
        a32 = jax.random.normal(jax.random.PRNGKey(9), (1024, 1024),
                                jnp.float32)
        b32 = jnp.zeros((1024, 1024), jnp.float32)
        if tuning.lookup("diff_topk_payload", shape=a32.shape, k=2048,
                         n=128, dtype=a32.dtype) is None:
            tuning.autotune_diff_topk_payload(a32, b32, k=2048, block=128,
                                              interpret=interp, reps=1)

    # -- JSON persistence round-trip --------------------------------------
    cache = tuning.get_cache()
    out_dir = os.path.join(os.path.dirname(__file__), "out")
    os.makedirs(out_dir, exist_ok=True)
    cache_path = os.path.join(out_dir, "tuning_cache.json")
    cache.save(cache_path)
    ok_roundtrip = tuning.TuningCache.load(cache_path).entries() \
        == cache.entries()

    write_csv("autotune", ["op", "case", "winner", "us_default", "us_tuned",
                           "err"], rows)
    report("autotune", us_tuned,
           f"cache_pinned={pinned}|entries={len(cache.entries())}"
           f"|scatter=tile={cfg_s.tile};chunk={cfg_s.chunk}"
           f"|hess_block={cfg_h.block}|topk_pallas={cfg_t.use_pallas}"
           f"|us_default={us_default:.0f}|us_tuned={us_tuned:.0f}"
           f"|claim_tuned_exact={ok_exact}"
           f"|claim_tuned_not_slower={ok_speed}"
           f"|claim_cache_roundtrip={ok_roundtrip}")


def server_aggregate(fast=False):
    """Payload-space server aggregation micro-benchmark: for an n-silo
    stack of compressed (d, d) Hessian-diff payloads, time the
    structure-aware ``Compressor.aggregate`` fast path (one dense
    accumulator) against the decompress-then-mean fallback (the
    (n, d, d) stack the PR-2 era server built), over an n x d sweep —
    now including LLM-diagonal-scale d in {1024, 2048, 4096}, where the
    Pallas path runs the TILED accumulator kernel (the single-block
    ceiling was d ~ 1500). Claims: fast == fallback to f64 tolerance
    everywhere, the sparse fast paths are >= 2x at n >= 32, d >= 256,
    and the forced tiled kernel reproduces the fallback exactly at
    every large d (d = 2048 in --fast — the CI smoke case). The large-d
    rows also time the AUTOTUNED dispatch (no explicit tile/chunk — the
    active tuning cache decides, the CI pin or the winners the
    ``autotune`` bench just recorded) against the untuned
    (512, 512)/512 default, pinning that the tuned config changes
    nothing numerically. The cross-device rows (n in {1k, 10k} silos,
    payload-space only) pin the streamed silo-slab path bitwise equal
    to the stacked kernel under cohort weights, with the staged slab
    bounded by the VMEM budget regardless of n."""
    from repro.core import BlockTopK, Compressor, RankR, TopK
    from repro.kernels.scatter_accum import scatter_accumulate
    from repro.kernels.tuning import lookup as tuned_lookup

    shapes = [(8, 128), (32, 256)] if fast else [
        (8, 256), (32, 256), (32, 512), (64, 512)]
    # large-d sweep: modest n and k keep the interpret-mode tiled kernel
    # (CPU) affordable; on TPU the same dispatch compiles the real thing
    big = [(2, 2048)] if fast else [(2, 1024), (2, 2048), (2, 4096)]

    def bench(fn, arg, reps=10):
        out = jax.block_until_ready(fn(arg))  # compile
        t0 = time.time()
        for _ in range(reps):
            out = fn(arg)
        jax.block_until_ready(out)
        return out, (time.time() - t0) * 1e6 / reps

    rows, fields = [], []
    ok_match, ok_speed, ok_tiled, ok_tuned = True, True, True, True
    us_total = 0.0
    interp = jax.default_backend() != "tpu"
    for n, d in big:
        comp = TopK(k=256)
        diffs = jax.random.normal(jax.random.PRNGKey(0), (n, d, d))
        payloads = jax.block_until_ready(
            jax.jit(jax.vmap(comp.compress))(diffs))
        fallback = jax.jit(lambda P, c=comp, dd=d: Compressor.aggregate(
            c, P, (dd, dd)))
        fast_fn = jax.jit(lambda P, c=comp, dd=d: c.aggregate(P, (dd, dd)))
        out_slow, us_slow = bench(fallback, payloads)
        out_fast, us_fast = bench(fast_fn, payloads)
        # pin exactness of the TILED Pallas kernel (forced via tile= —
        # at d=1024 the f64 accumulator is exactly the 8 MiB budget, so
        # auto-dispatch would still pick the single-block kernel), and
        # time the forced default config against the autotuned dispatch
        # (tile/chunk omitted: the active tuning cache decides)
        t_def = lambda P, dd=d: scatter_accumulate(
            P.values, P.indices, (dd, dd), use_pallas=True,
            interpret=interp, tile=(512, 512), chunk=512) / n
        t_tuned = lambda P, dd=d: scatter_accumulate(
            P.values, P.indices, (dd, dd), use_pallas=True,
            interpret=interp) / n
        tiled, us_tdef = bench(t_def, payloads, reps=1)
        tuned, us_ttun = bench(t_tuned, payloads, reps=1)
        cfg = tuned_lookup("scatter_accumulate", shape=(d, d),
                           k=payloads.values.shape[1], n=n,
                           dtype=payloads.values.dtype)
        cfg_desc = ("default" if cfg is None
                    else f"tile={cfg.tile};chunk={cfg.chunk}")
        scale = float(jnp.max(jnp.abs(out_slow))) + 1e-30
        err = float(jnp.max(jnp.abs(out_fast - out_slow)))
        err_t = float(jnp.max(jnp.abs(tiled - out_slow)))
        err_tu = float(jnp.max(jnp.abs(tuned - out_slow)))
        speedup = us_slow / max(us_fast, 1e-9)
        ok_match &= err <= 1e-12 * max(1.0, scale)
        ok_tiled &= err_t <= 1e-12 * max(1.0, scale)
        ok_tuned &= err_tu <= 1e-12 * max(1.0, scale)
        us_total += us_fast
        rows.append((n, d, "topk-tiled", us_slow, us_fast, speedup, err,
                     us_tdef, us_ttun, cfg_desc))
        fields.append(f"n{n}d{d}:topk={speedup:.1f}x;tiled_err={err_t:.1e};"
                      f"tuned={cfg_desc}")
    for n, d in shapes:
        diffs = jax.random.normal(jax.random.PRNGKey(0), (n, d, d))
        diffs = 0.5 * (diffs + jnp.swapaxes(diffs, -1, -2))
        keys = jax.random.split(jax.random.PRNGKey(1), n)
        cases = {
            "topk": TopK(k=4 * d),
            "blocktopk": BlockTopK(k_per_block=64, block=128),
            "rankr": RankR(4),
        }
        cell = []
        for name, comp in cases.items():
            payloads = jax.block_until_ready(
                jax.jit(jax.vmap(comp.compress))(diffs, keys))
            # the PR-2 era server: decompress every silo, mean the stack
            fallback = jax.jit(lambda P, c=comp: Compressor.aggregate(
                c, P, (d, d)))
            fast_fn = jax.jit(lambda P, c=comp: c.aggregate(P, (d, d)))
            out_slow, us_slow = bench(fallback, payloads)
            out_fast, us_fast = bench(fast_fn, payloads)
            err = float(jnp.max(jnp.abs(out_fast - out_slow)))
            scale = float(jnp.max(jnp.abs(out_slow))) + 1e-30
            speedup = us_slow / max(us_fast, 1e-9)
            ok_match &= err <= 1e-12 * max(1.0, scale)
            if name in ("topk", "blocktopk") and n >= 32 and d >= 256:
                ok_speed &= speedup >= 2.0
            us_total += us_fast
            rows.append((n, d, name, us_slow, us_fast, speedup, err,
                         "", "", ""))
            cell.append(f"{name}={speedup:.1f}x")
        fields.append(f"n{n}d{d}:" + ";".join(cell))

    # -- cross-device scale: streamed vs stacked over thousands of silos --
    # Synthetic TopK pair streams built DIRECTLY in payload space (an
    # (n, d, d) dense stack at n = 10k would be 20 GiB — the exact
    # thing this path exists to never materialize). Weights come from
    # the cohort layer: K-of-N sampling + deadline/staleness discount
    # on the fl-cross-device link, applied through
    # ``Compressor.aggregate(..., weights=)``. At both sizes the
    # concrete pair stream outgrows the 8 MiB VMEM budget, so the
    # aggregate auto-dispatches the streamed silo-slab path; the
    # comparator runs the same scaled payloads through the stacked
    # kernel (jit keeps ``_should_stream`` off the traced path).
    # Claims: streamed == stacked BITWISE at every n, and the streamed
    # slab never stages more than the VMEM budget of pairs.
    from repro.core.cohort import (
        CohortSpec,
        arrival_times,
        on_time_mask,
        sample_cohort,
        staleness_weights,
    )
    from repro.core.compressors import SparsePayload, scale_payload
    from repro.kernels import VMEM_BUDGET_BYTES
    from repro.kernels.scatter_accum import silo_chunk_for

    ok_stream, ok_chunk = True, True
    k_pairs, d_acc = 1024, 512
    for n_cd in ([1000] if fast else [1000, 10000]):
        spec = CohortSpec(cohort=max(1, n_cd // 10), population=n_cd,
                          link="fl-cross-device", seed=0)
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        payloads = SparsePayload(
            values=jax.random.normal(ks[0], (n_cd, k_pairs)),
            indices=jax.random.randint(ks[1], (n_cd, k_pairs), 0,
                                       d_acc * d_acc, dtype=jnp.int32),
            universe=d_acc * d_acc)
        comp = TopK(k=k_pairs)
        active = sample_cohort(ks[2], n_cd, spec.cohort)
        times = arrival_times(spec, n_cd, bits_per_silo=96 * k_pairs)
        on_time = jnp.asarray(on_time_mask(times, spec.deadline_quantile))
        late = staleness_weights(jnp.ones((n_cd,), jnp.int32),
                                 spec.staleness_beta)
        wts = jnp.where(active, jnp.where(on_time, 1.0, late), 0.0)
        pair = (payloads.values.dtype.itemsize
                + payloads.indices.dtype.itemsize)
        chunk = silo_chunk_for(k_pairs, payloads.values.dtype)
        ok_chunk &= chunk * k_pairs * pair <= VMEM_BUDGET_BYTES
        streamed_fn = lambda P, c=comp, dd=d_acc, w=wts: c.aggregate(
            P, (dd, dd), weights=w)           # eager: streams
        # stacked comparator: the SAME eagerly-scaled pairs through the
        # stacked kernel (jitting the whole aggregate would let XLA
        # reassociate the x*w and /n multiplies and shift last bits)
        scaled = scale_payload(payloads, wts)
        stacked_fn = lambda _, s=scaled, dd=d_acc, m=n_cd: (
            scatter_accumulate(s.values, s.indices, (dd, dd)) / m
        ).reshape(dd, dd)
        out_stacked, us_stacked = bench(stacked_fn, payloads, reps=3)
        out_streamed, us_streamed = bench(streamed_fn, payloads, reps=3)
        exact = bool(jnp.array_equal(out_streamed, out_stacked))
        ok_stream &= exact
        err_s = float(jnp.max(jnp.abs(out_streamed - out_stacked)))
        us_total += us_streamed
        rows.append((n_cd, d_acc, "topk-streamed", us_stacked,
                     us_streamed, us_stacked / max(us_streamed, 1e-9),
                     err_s, "", "", f"silo_chunk={chunk}"))
        fields.append(f"n{n_cd}d{d_acc}:streamed_exact={exact};"
                      f"chunk={chunk}")

    write_csv("server_aggregate",
              ["n", "d", "compressor", "us_decompress_mean", "us_aggregate",
               "speedup", "max_abs_err", "us_tiled_default",
               "us_tiled_tuned", "tuned_cfg"], rows)
    report("server_aggregate", us_total,
           "|".join(fields)
           + f"|claim_fast_matches_fallback={ok_match}"
           f"|claim_sparse_speedup_ge_2x={ok_speed}"
           f"|claim_tiled_matches_fallback={ok_tiled}"
           f"|claim_tuned_matches_fallback={ok_tuned}"
           f"|claim_streamed_matches_stacked={ok_stream}"
           f"|claim_stream_chunk_le_budget={ok_chunk}")


def precond_step(fast=False):
    """second_order/fednl_precond update micro-benchmark: the payload-op
    path (compress through the payload-emitting op, H reconstructed via
    the payload-space scatter — the shipped code) vs the PR-3-era
    dense-mask path (codec compress building (nblocks, block^2)
    selection masks + dense decompress round-trip inside every step),
    on a (d, d) parameter tensor. Claims: the payload path is no slower
    at d >= 1024 (off-TPU both are jnp; on TPU the payload path is the
    Pallas kernel), the two paths learn the same H on tie-free data,
    and the AUTOTUNED dispatch of the payload step (tracing under the
    active tuning cache — the CI pin or the ``autotune`` bench's
    winners) learns the same H as tracing under an empty cache (the
    untuned defaults)."""
    from repro.kernels import tuning
    from repro.second_order.fednl_precond import (FedNLPrecondOptimizer,
                                                  _as2d)

    ds = [1024] if fast else [1024, 2048]

    def bench(fn, *args, reps=5):
        out = jax.block_until_ready(fn(*args))  # compile
        t0 = time.time()
        for _ in range(reps):
            out = fn(*args)
        jax.block_until_ready(out)
        return out, (time.time() - t0) * 1e6 / reps

    rows, fields = [], []
    ok_speed, ok_match, ok_tuned, us_total = True, True, True, 0.0
    for d in ds:
        opt = FedNLPrecondOptimizer(lr=1e-3, k_per_block=2048, block=128)
        comp = opt.compressor
        params = {"w": jnp.zeros((d, d), jnp.float32)}
        grads = {"w": jax.random.normal(jax.random.PRNGKey(0), (d, d),
                                        jnp.float32)}
        state = opt.init(params)

        def dense_mask_update(g, s):
            # the PR-3-era per-tensor body: codec round-trip (compress
            # builds the dense per-tile selection masks)
            h = s.h["w"]
            diff = g["w"].astype(jnp.float32) ** 2 - h
            sd = comp.decompress(comp.compress(_as2d(diff)),
                                 _as2d(h).shape).reshape(h.shape)
            l = jnp.sqrt(jnp.mean(diff * diff) + 1e-30)
            denom = jnp.sqrt(jnp.maximum(h, 0.0)) + jnp.sqrt(l) + opt.eps
            m_new = opt.momentum * s.mu["w"] + g["w"] / denom
            return (-opt.lr * m_new,
                    type(s)(s.step + 1, {"w": h + opt.alpha * sd},
                            {"w": m_new}))

        # tuned column: the SAME update traced twice — once under an
        # empty tuning cache (untuned default dispatch) and once under
        # the ambient cache (the CI pin / autotune winners). Fresh jit
        # lambdas per cache state: dispatch resolves at trace time.
        ambient = tuning.get_cache()
        try:
            tuning.set_cache(tuning.TuningCache())
            default_fn = jax.jit(lambda g, s: opt.update(g, s, params))
            (_, st_def), us_payload_def = bench(default_fn, grads, state)
        finally:
            tuning.set_cache(ambient)
        payload_fn = jax.jit(lambda g, s: opt.update(g, s, params))
        dense_fn = jax.jit(dense_mask_update)
        (_, st_p), us_payload = bench(payload_fn, grads, state)
        (_, st_d), us_dense = bench(dense_fn, grads, state)
        err = float(jnp.max(jnp.abs(st_p.h["w"] - st_d.h["w"])))
        err_tuned = float(jnp.max(jnp.abs(st_p.h["w"] - st_def.h["w"])))
        speedup = us_dense / max(us_payload, 1e-9)
        if d >= 1024:
            ok_speed &= speedup >= 0.95  # "no slower" with timer noise
        ok_match &= err <= 1e-5
        ok_tuned &= err_tuned <= 1e-6  # f32 state; 0 when configs agree
        us_total += us_payload
        rows.append((d, us_dense, us_payload, speedup, err,
                     us_payload_def, err_tuned))
        fields.append(f"d{d}:payload={us_payload:.0f}us;"
                      f"densemask={us_dense:.0f}us;{speedup:.1f}x;"
                      f"default={us_payload_def:.0f}us")

    write_csv("precond_step",
              ["d", "us_dense_mask", "us_payload", "speedup", "max_h_err",
               "us_payload_default", "max_h_err_tuned"],
              rows)
    report("precond_step", us_total,
           "|".join(fields)
           + f"|claim_payload_not_slower={ok_speed}"
           f"|claim_same_h={ok_match}"
           f"|claim_tuned_same_h={ok_tuned}")


def train_step(fast=False):
    """What FedNL costs per token on a real architecture: end-to-end
    jitted train-step time and tokens/sec for fednl vs adamw on reduced
    (smoke) configs of >=2 model-zoo archs, across >=2 curvature refresh
    intervals. refresh_every=1 pays the full observation+learning cost
    every step (the paper's per-round placement); refresh_every=16
    amortizes it — non-refresh steps are just the elementwise diagonal
    solve, so the amortized cost approaches adamw. Claim: amortized
    fednl step-time at refresh_every=16 stays within 3x of adamw on
    every arch (timing claims stay local-only for the speedup benches;
    this one is a bound with 3x headroom, so it holds on shared CI
    runners too)."""
    from repro.configs import get_config
    from repro.data.tokens import TokenPipeline
    from repro.launch.steps import make_optimizer, make_train_step
    from repro.models import build_model

    archs = ["qwen2-0.5b", "xlstm-350m"]
    b, t = (2, 32) if fast else (4, 64)
    reps = 2 if fast else 4
    n_silos, r_long, bound = 2, 16, 3.0

    def run_steps(step_fn, params, state, batch, n):
        out = None
        t0 = time.time()
        for _ in range(n):
            params, state, out = step_fn(params, state, batch)
        jax.block_until_ready(out["loss"])
        return (time.time() - t0) * 1e6 / n, params, state

    rows, fields = [], []
    ok_bound, ok_finite, us_total = True, True, 0.0
    for arch in archs:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg, use_remat=True)
        params0 = model.init_params(jax.random.PRNGKey(0))
        pipe = TokenPipeline(vocab_size=cfg.vocab, seq_len=t,
                             global_batch=b, seed=0)
        batch = pipe.batch(0)

        def cell(opt_name, refresh_every, **kw):
            opt = make_optimizer(opt_name, 1e-3, **kw)
            step_fn = jax.jit(make_train_step(
                model, opt, refresh_every=refresh_every, n_silos=n_silos))
            params, state = params0, opt.init(params0)
            # warm step: compiles BOTH lax.cond branches and runs the
            # step-0 refresh, so timed steps measure steady state
            _, params, state = run_steps(step_fn, params, state, batch, 1)
            us, params, state = run_steps(step_fn, params, state, batch,
                                          reps)
            return us, state

        fk = dict(k_per_block=256, block=128)
        us_adamw, _ = cell("adamw", 1)
        us_refresh, st1 = cell("fednl", 1, **fk)      # every step refreshes
        us_quiet, st16 = cell("fednl", r_long, **fk)  # none of the timed do
        us_amort = (us_refresh + (r_long - 1) * us_quiet) / r_long
        toks = lambda us: b * t / us * 1e6
        ok_bound &= us_amort <= bound * us_adamw
        ok_finite &= all(bool(jnp.all(jnp.isfinite(x)))
                         for st in (st1, st16) for x in jax.tree.leaves(st.h))
        us_total += us_adamw + us_refresh + us_quiet
        rows.append((arch, us_adamw, us_refresh, us_quiet, us_amort,
                     toks(us_adamw), toks(us_refresh), toks(us_amort)))
        fields.append(f"{arch}:adamw={us_adamw:.0f}us;"
                      f"fednl_r1={us_refresh:.0f}us;"
                      f"fednl_r16={us_amort:.0f}us;"
                      f"tok/s={toks(us_amort):.0f}")

    write_csv("train_step",
              ["arch", "us_adamw", "us_fednl_refresh", "us_fednl_quiet",
               "us_fednl_r16_amortized", "toks_adamw", "toks_fednl_r1",
               "toks_fednl_r16"],
              rows)
    report("train_step", us_total,
           "|".join(fields)
           + f"|claim_fednl16_amortized_le_3x_adamw={ok_bound}"
           f"|claim_curvature_finite={ok_finite}")


def engine_vmap(fast=False):
    """The engine's headline: an s-seed cell as ONE vmapped jitted program
    vs s serial per-seed runs (the seed-era execution model)."""
    prob = problem("phishing")
    d, n = prob["d"], prob["n"]
    x0 = _near_x0(prob)
    seeds = (0, 1, 2) if fast else (0, 1, 2, 3)
    rounds = 40

    t0 = time.time()
    alg = FedNL(prob["grad"], prob["hess"], RankR(1), option=1, mu=1e-3)
    serial = [alg.run(x0, n, rounds, seed=s)[1] for s in seeds]
    jax.block_until_ready(serial[-1])
    us_serial = (time.time() - t0) * 1e6

    spec = ExperimentSpec("fednl", "rankr", 1,
                          params=dict(option=1, mu=1e-3),
                          seeds=seeds, num_rounds=rounds)
    t0 = time.time()
    res = Sweep([spec]).run(prob, x0=x0)
    us_vmap = (time.time() - t0) * 1e6

    cell = res.cells[0]
    err = max(float(np.max(np.abs(cell.xs[i] - np.asarray(serial[i]))))
              for i in range(len(seeds)))
    speedup = us_serial / max(us_vmap, 1.0)
    report("engine_vmap", us_vmap,
           f"seeds={len(seeds)}|us_serial={us_serial:.0f}|us_vmap={us_vmap:.0f}"
           f"|speedup={speedup:.2f}x|max_abs_diff={err:.2e}"
           f"|claim_speedup_ge_3x={speedup >= 3.0}")


def roofline(fast=False):
    path = os.path.join(os.path.dirname(__file__), "..",
                        "results_dryrun_1pod.jsonl")
    if not os.path.exists(path):
        report("roofline", 0.0, "missing results_dryrun_1pod.jsonl (run "
               "python -m repro.launch.dryrun --all --out ...)")
        return
    rows = [json.loads(l) for l in open(path)]
    ok = [r for r in rows if r["status"] == "ok"]
    skip = [r for r in rows if r["status"] == "skip"]
    csv_rows = [(r["arch"], r["shape"], r["t_compute_s"], r["t_memory_s"],
                 r["t_collective_s"], r["bottleneck"], r["useful_ratio"],
                 r["peak_bytes_per_device"]) for r in ok]
    write_csv("roofline", ["arch", "shape", "t_compute", "t_memory",
                           "t_collective", "bottleneck", "useful_ratio",
                           "peak_bytes_per_device"], csv_rows)
    bcounts = {}
    for r in ok:
        bcounts[r["bottleneck"]] = bcounts.get(r["bottleneck"], 0) + 1
    report("roofline", 0.0,
           f"pairs_ok={len(ok)}|skips={len(skip)}|bottlenecks={bcounts}")


BENCHES = [fig2_local, fig2_global, fig2_nl1, fig3_compression, fig4_options,
           fig6_update_rules, fig7_bc, fig9_pp, fig14_heterogeneity,
           table2_rates, payload_roundtrip, codec_roundtrip, autotune,
           server_aggregate, precond_step, train_step, engine_vmap,
           roofline]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated bench names to run")
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write results as JSON (one object per "
                         "bench: name, us_per_call, derived) — the "
                         "BENCH_*.json artifact the CI bench-smoke lane "
                         "uploads")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for bench in BENCHES:
        if args.only and bench.__name__ not in args.only.split(","):
            continue
        try:
            bench(fast=args.fast)
        except Exception as e:  # noqa: BLE001
            import traceback

            traceback.print_exc()
            report(bench.__name__, 0.0, f"ERROR:{type(e).__name__}:{e}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump([dict(name=n, us_per_call=u, derived=d)
                       for n, u, d in RESULTS], f, indent=2)


if __name__ == "__main__":
    main()
