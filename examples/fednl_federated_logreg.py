"""End-to-end paper experiment: FedNL vs first-order baselines on the
a1a-shaped problem (Table 3 sizes), with the paper's communicated-bits
accounting, PLUS the same FedNL executed distributed via shard_map (the
production execution path, silo data sharded over the mesh).

    PYTHONPATH=src python examples/fednl_federated_logreg.py
"""

import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import FedNL, RandomDithering, RankR
from repro.core.baselines import Diana, gd_run
from repro.core.compressors import FLOAT_BITS
from repro.core.federated import run_fednl_sharded
from repro.core.newton import newton_run
from repro.core.objectives import (batch_grad, batch_hess, global_value,
                                   lipschitz_constants)
from repro.data.synthetic import make_libsvm_like

data = make_libsvm_like(jax.random.PRNGKey(0), "a1a", lam=1e-3)
n, m, d = data.a.shape
grad_fn = lambda x: batch_grad(x, data)
hess_fn = lambda x: batch_hess(x, data)
val_fn = lambda x: global_value(x, data)
consts = lipschitz_constants(data)
xstar, _ = newton_run(jnp.zeros(d), grad_fn, hess_fn, 25)
fstar = float(val_fn(xstar))
x0 = xstar + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (d,))

print(f"a1a-shaped: n={n} silos, m={m} points/silo, d={d}, "
      f"kappa~{consts['L'] / 1e-3:.0f}")

# --- FedNL (vmap execution) --------------------------------------------------
alg = FedNL(grad_fn, hess_fn, RankR(1), option=1, mu=1e-3)
_, xs = alg.run(x0, n, 20)
bits = [alg.init_bits(d) + k * alg.bits_per_round(d) for k in range(len(xs))]
print("\nFedNL (Rank-1):    bits/node        f - f*")
for k in (0, 2, 5, 10, 15, 20):
    print(f"  round {k:3d}   {bits[k]:12.3e}   {float(val_fn(xs[k])) - fstar:.3e}")

# --- the same algorithm, sharded over the mesh --------------------------------
mesh = jax.make_mesh((jax.device_count(),), ("data",))
_, xs_sh = run_fednl_sharded(data, RankR(1), mesh, x0, 10, option=2)
print(f"\nshard_map execution over {jax.device_count()} device(s): "
      f"gap after 10 rounds = {float(val_fn(xs_sh[-1])) - fstar:.3e}")

# --- baselines ------------------------------------------------------------------
_, xs_gd = gd_run(x0, grad_fn, 1.0 / consts["L"], 2000)
rd = RandomDithering(s=int(d ** 0.5))
diana = Diana(grad_fn, rd, consts["L"], n, rd.omega_for((d,)))
_, xs_di = diana.run(x0, n, 2000)

gap_gd = float(val_fn(xs_gd[-1])) - fstar
gap_di = float(val_fn(xs_di[-1])) - fstar
bits_gd = 2000 * d * FLOAT_BITS
bits_di = 2000 * diana.bits_per_round(d)
print(f"\nGD    after {bits_gd:.2e} bits/node: gap {gap_gd:.3e}")
print(f"DIANA after {bits_di:.2e} bits/node: gap {gap_di:.3e}")
print(f"FedNL after {bits[20]:.2e} bits/node: gap "
      f"{float(val_fn(xs[20])) - fstar:.3e}   <-- the paper's headline")
