"""End-to-end paper experiment: FedNL vs first-order baselines on the
a1a-shaped problem (Table 3 sizes), with the paper's communicated-bits
accounting, PLUS the same FedNL executed distributed via shard_map (the
production execution path, silo data sharded over the mesh).

    PYTHONPATH=src python examples/fednl_federated_logreg.py
"""

import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core import RandomDithering
from repro.core.baselines import Diana, gd_run
from repro.core.compressors import FLOAT_BITS
from repro.core.newton import newton_run
from repro.core.objectives import (batch_grad, batch_hess, global_value,
                                   lipschitz_constants)
from repro.data.synthetic import make_libsvm_like
from repro.engine import ExperimentSpec, Sweep

data = make_libsvm_like(jax.random.PRNGKey(0), "a1a", lam=1e-3)
n, m, d = data.a.shape
grad_fn = lambda x: batch_grad(x, data)
hess_fn = lambda x: batch_hess(x, data)
val_fn = lambda x: global_value(x, data)
consts = lipschitz_constants(data)
xstar, _ = newton_run(jnp.zeros(d), grad_fn, hess_fn, 25)
fstar = float(val_fn(xstar))
x0 = xstar + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (d,))
prob = dict(data=data, grad=grad_fn, hess=hess_fn, val=val_fn, n=n, d=d,
            fstar=fstar)

print(f"a1a-shaped: n={n} silos, m={m} points/silo, d={d}, "
      f"kappa~{consts['L'] / 1e-3:.0f}")

# --- FedNL (vmap execution through the engine) --------------------------------
spec = ExperimentSpec("fednl", "rankr", 1, params=dict(option=1, mu=1e-3),
                      num_rounds=20, name="FedNL-Rank1")
cell = Sweep([spec]).run(prob, x0=x0).cells[0]
print("\nFedNL (Rank-1):    bits/node        f - f*")
for k in (0, 2, 5, 10, 15, 20):
    print(f"  round {k:3d}   {cell.bits[k]:12.3e}   {cell.gaps[0, k]:.3e}")

# --- the same spec, sharded over the mesh (core/federated.py path) ------------
mesh = jax.make_mesh((jax.device_count(),), ("data",))
spec_sh = ExperimentSpec("fednl", "rankr", 1, params=dict(option=2),
                         num_rounds=10, name="FedNL-sharded")
cell_sh = Sweep([spec_sh], mesh=mesh).run(prob, x0=x0).cells[0]
print(f"\nshard_map execution over {jax.device_count()} device(s): "
      f"gap after 10 rounds = {cell_sh.gaps[0, -1]:.3e}")

# --- baselines ------------------------------------------------------------------
_, xs_gd = gd_run(x0, grad_fn, 1.0 / consts["L"], 2000)
rd = RandomDithering(s=int(d ** 0.5))
diana = Diana(grad_fn, rd, consts["L"], n, rd.spec((d,)).omega)
_, xs_di = diana.run(x0, n, 2000)

gap_gd = float(val_fn(xs_gd[-1])) - fstar
gap_di = float(val_fn(xs_di[-1])) - fstar
bits_gd = 2000 * d * FLOAT_BITS
bits_di = 2000 * diana.bits_per_round(d)
print(f"\nGD    after {bits_gd:.2e} bits/node: gap {gap_gd:.3e}")
print(f"DIANA after {bits_di:.2e} bits/node: gap {gap_di:.3e}")
print(f"FedNL after {cell.bits[20]:.2e} bits/node: gap "
      f"{cell.gaps[0, 20]:.3e}   <-- the paper's headline")
