"""Fig. 14 scenario: how statistical heterogeneity (synthetic(alpha, beta))
affects FedNL vs gradient descent — FedNL cells run as declarative
engine sweeps (3 seeds stacked into one vmapped program per problem).

    PYTHONPATH=src python examples/heterogeneity.py
"""

import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp
import numpy as np

from repro.core.baselines import gd_run
from repro.core.newton import newton_run
from repro.core.objectives import (batch_grad, batch_hess, global_value,
                                   lipschitz_constants)
from repro.data.synthetic import make_iid, make_synthetic
from repro.engine import ExperimentSpec, Sweep

SPEC = ExperimentSpec("fednl", "rankr", 1, params=dict(option=2),
                      seeds=(0, 1, 2), num_rounds=15, name="FedNL")

for tag, maker in [
    ("IID", lambda k: make_iid(k, n=30, m=200, d=100)),
    ("synthetic(0,0)", lambda k: make_synthetic(k, 0.0, 0.0)),
    ("synthetic(1,1)", lambda k: make_synthetic(k, 1.0, 1.0)),
]:
    data = maker(jax.random.PRNGKey(0))
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    val_fn = lambda x: global_value(x, data)
    d, n = data.a.shape[-1], data.a.shape[0]
    xstar, _ = newton_run(jnp.zeros(d), grad_fn, hess_fn, 25)
    fstar = float(val_fn(xstar))
    x0 = xstar + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (d,))

    prob = dict(grad=grad_fn, hess=hess_fn, val=val_fn, n=n, d=d, fstar=fstar)
    res = Sweep([SPEC]).run(prob, x0=x0)
    cell = res.cells[0]
    gap_fednl = float(np.max(cell.gaps[:, -1]))  # worst of the 3 seeds

    _, xs_gd = gd_run(x0, grad_fn, 1.0 / lipschitz_constants(data)["L"], 1500)

    print(f"{tag:16s} FedNL gap@15 rounds (worst of 3 seeds): {gap_fednl:9.2e}"
          f"   GD gap@1500 rounds: {float(val_fn(xs_gd[-1])) - fstar:9.2e}")
print("\nFedNL is insensitive to heterogeneity; GD's tail is kappa-limited "
      "regardless (the paper's Fig. 14 story).")
