"""Quickstart: FedNL (Algorithm 1) on a federated logistic regression,
constructed declaratively through the experiment engine's registry.

    PYTHONPATH=src python examples/quickstart.py
"""

import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp

from repro.core.objectives import batch_grad, batch_hess, global_value
from repro.data.synthetic import make_synthetic
from repro.engine import Oracles, available_methods, build_compressor, make_method

# 1. a cross-silo problem: n=16 silos, heterogeneous data (Sec. A.14)
data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                      n=16, m=100, d=60, lam=1e-3)
oracles = Oracles(
    value=lambda x: global_value(x, data),  # x -> f(x)
    grad=lambda x: batch_grad(x, data),     # x -> (n, d) per-silo gradients
    hess=lambda x: batch_hess(x, data),     # x -> (n, d, d) per-silo Hessians
)

# 2. any method in the family is constructible by name; FedNL with Rank-1
#    compression is the paper's best configuration
print("registered methods:", ", ".join(available_methods()))
alg = make_method("fednl", oracles, build_compressor("rankr", 1),
                  alpha=1.0, option=1, mu=1e-3)

# 3. run 20 communication rounds (the scan driver comes with the method)
x0 = jnp.zeros(60)
final, xs = alg.run(x0, n=16, num_rounds=20)

for k in (0, 1, 2, 5, 10, 20):
    print(f"round {k:3d}  f(x) = {float(global_value(xs[k], data)):.12f}")
print(f"\nuplink per device per round: {alg.bits_per_round(60) / 8:.0f} bytes "
      f"(vs {60 * 61 // 2 * 8} bytes for a full Hessian)")
