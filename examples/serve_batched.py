"""Batched serving demo: prefill a batch of prompts and decode with the
KV/state caches — runs any of the ten architectures (reduced configs on
CPU; same code path as the decode_32k / long_500k dry-run shapes).

    PYTHONPATH=src python examples/serve_batched.py --arch xlstm-350m
"""

import argparse
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-350m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()
    seqs = generate(args.arch, smoke=True, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen)
    for i in range(min(2, args.batch)):
        print(f"request {i}: {seqs[i].tolist()}")


if __name__ == "__main__":
    main()
