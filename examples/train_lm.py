"""End-to-end LM training driver: a qwen2-family model trained for a few
hundred steps with either AdamW or the paper-derived FedNL structured-
curvature preconditioner (--optimizer fednl).

Defaults are sized for the CPU container (a ~15M-param reduced config,
200 steps, ~minutes). `--full` selects the real qwen2-0.5b config — the
same script, pointed at a TPU slice, is the production path the dry-run
proves out.

    PYTHONPATH=src python examples/train_lm.py --steps 200 --optimizer fednl

Second-order walkthrough (--optimizer fednl): the train step splits the
global batch over the mesh data axis — each shard plays one FedNL silo.
Every --refresh-every steps (a jittable lax.cond, so intermediate steps
pay nothing) each silo takes a local curvature observation — the
empirical-Fisher g^2 diagonal, or a Hutchinson z*(Hz) probe with --hvp —
compresses the diff against the shared estimate H through the fused
Block-TopK payload kernel (--curvature-k values per 128x128 block, the
paper's C(D - H) uplink), and H learns from the payload-space server
mean: H <- H + alpha*C(D - H), with the Option-2 ridge l = ||D - H||_F
making sqrt(H) + sqrt(l) a safe diagonal preconditioner. All other steps
just apply that stored preconditioner — per-step cost is elementwise, and
the driver logs the uplink cost as curv_bits next to loss/gnorm.
"""

import argparse
import os, sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "fednl"])
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--refresh-every", type=int, default=4)
    ap.add_argument("--curvature-k", type=int, default=2048)
    ap.add_argument("--hvp", action="store_true")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()

    hist = train(args.arch, smoke=not args.full, steps=args.steps,
                 batch=args.batch, seq=args.seq, lr=args.lr,
                 optimizer=args.optimizer, ckpt=args.ckpt,
                 refresh_every=args.refresh_every,
                 curvature_k=args.curvature_k, hvp=args.hvp)
    print(f"\nloss: {hist[0]:.3f} -> {hist[-1]:.3f} over {args.steps} steps "
          f"({args.optimizer})")


if __name__ == "__main__":
    main()
