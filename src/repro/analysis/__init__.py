"""``repro.analysis`` — static enforcement of the data-path invariants.

The repo's core claims — the uplink is dense-free end to end, kernels
fit the VMEM dispatch budget, ``-1`` payload padding never aliases a
real index, f64 numerics are never silently downcast, jitted hot paths
never sync with the host — used to live in one-off hand-written tests
(or nowhere). This package turns each claim into a ``Rule`` over traced
programs: every registered ``Method`` step, ``Compressor.aggregate``
path, and Pallas kernel op is traced via ``jax.make_jaxpr`` /
``jax.eval_shape`` (trace-only — runs on CPU CI, no TPU needed) and the
closed jaxpr is walked by a registry of rules mirroring the engine's
method/compressor registries.

Entry points:

  check(fn, *args, rules=..., context=...)   one-line pytest assertion
  analyze(...)                               full registry sweep
  python -m repro.launch.analyze             CLI (text/JSON, CI lane)

Rules self-register in ``rules.py`` / ``source_rules.py`` (imported
here so the registry is populated on package import).
"""

from . import rules as _rules, source_rules as _source_rules  # noqa: F401
from .framework import (
    AnalysisError,
    Rule,
    Target,
    Violation,
    available_rules,
    check,
    get_rule,
    register_rule,
)
from .reporters import render_json, render_text
from .targets import analyze, iter_targets

__all__ = [
    "AnalysisError",
    "Rule",
    "Target",
    "Violation",
    "analyze",
    "available_rules",
    "check",
    "get_rule",
    "iter_targets",
    "register_rule",
    "render_json",
    "render_text",
]
