"""Rule protocol, rule registry, and the ``check`` entry point.

A ``Rule`` inspects one traced program (a closed jaxpr) in the context
of one ``Target`` and returns ``Violation``s. Rules self-register in a
string-keyed registry (mirroring the engine's Method/Compressor
registries) so CLIs and tests can select them declaratively
(``--rule vmem-budget``, ``check(fn, x, rules=["no-host-sync"])``).

Source-level rules (kind="source") receive a file path + AST instead of
a jaxpr — same registry, same reporting surface.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence

import jax


@dataclasses.dataclass(frozen=True)
class Violation:
    """One rule violation at one site of one target."""

    rule: str
    target: str
    message: str
    site: Optional[str] = None  # eqn summary / file:line

    def __str__(self) -> str:
        loc = f" [{self.site}]" if self.site else ""
        return f"{self.target}: {self.rule}: {self.message}{loc}"


@dataclasses.dataclass(frozen=True)
class Target:
    """One analyzable program.

    name:    stable identifier ("method:fednl[topk]", "kernel:...")
    kind:    "method-step" | "aggregate" | "precond" | "kernel" | "source"
    trace:   zero-arg callable returning the ClosedJaxpr (lazy — targets
             are enumerable without paying tracing cost; "source" targets
             return the file path instead)
    rules:   rule names that apply to this target
    context: rule parameters (silo axis n, dense_shape, block, budget,
             ... — whatever the target's rules consume)
    """

    name: str
    kind: str
    trace: Callable[[], Any]
    rules: tuple
    context: dict = dataclasses.field(default_factory=dict)


class Rule:
    """Base class: subclass, set ``name``/``description``, implement
    ``check(traced, target) -> list[Violation]`` where ``traced`` is the
    target's ``trace()`` output (a ClosedJaxpr for jaxpr rules, a file
    path for source rules). Register with ``@register_rule``."""

    name: str = ""
    description: str = ""
    kinds: tuple = ()  # target kinds this rule understands ((): any)

    def check(self, traced, target: Target) -> list:
        raise NotImplementedError

    def violation(self, target: Target, message: str,
                  site: Optional[str] = None) -> Violation:
        return Violation(rule=self.name, target=target.name,
                         message=message, site=site)


_RULES: dict[str, Rule] = {}


def register_rule(cls):
    """Class decorator: instantiate and register under ``cls.name``.
    Re-registration overwrites (last wins) so notebooks can hot-patch."""
    inst = cls()
    assert inst.name, cls
    _RULES[inst.name] = inst
    return cls


def available_rules() -> list:
    return sorted(_RULES)


def get_rule(name: str) -> Rule:
    try:
        return _RULES[name]
    except KeyError:
        raise KeyError(
            f"unknown rule {name!r}; available: {available_rules()}"
        ) from None


def rule_descriptions() -> dict:
    return {name: _RULES[name].description for name in available_rules()}


class AnalysisError(AssertionError):
    """Raised by ``check`` when a traced program violates a rule."""

    def __init__(self, violations: Sequence[Violation]):
        self.violations = list(violations)
        lines = "\n".join(f"  - {v}" for v in self.violations)
        super().__init__(
            f"{len(self.violations)} static-analysis violation(s):\n{lines}")


def run_rules(target: Target) -> list:
    """Trace ``target`` once and run all its rules."""
    traced = target.trace()
    out = []
    for rname in target.rules:
        rule = get_rule(rname)
        if rule.kinds and target.kind not in rule.kinds:
            continue
        out.extend(rule.check(traced, target))
    return out


def check(fn, *args, rules, name: Optional[str] = None, kind: str = "check",
          context: Optional[dict] = None, raise_on_violation: bool = True,
          **trace_kwargs) -> list:
    """One-line pytest integration: trace ``fn(*args)`` and assert the
    given rules hold.

        analysis.check(lambda g, s: opt.update(g, s, params), grads,
                       state, rules=["no-dense-roundtrip"],
                       context={"block": 128})

    ``args`` may be concrete arrays or ``jax.ShapeDtypeStruct``s (the
    trace never executes the function). Returns the violations (empty on
    success); raises ``AnalysisError`` unless ``raise_on_violation`` is
    False.
    """
    target = Target(
        name=name or getattr(fn, "__name__", "check"),
        kind=kind,
        trace=lambda: jax.make_jaxpr(fn, **trace_kwargs)(*args),
        rules=tuple(rules),
        context=dict(context or {}),
    )
    violations = run_rules(target)
    if violations and raise_on_violation:
        raise AnalysisError(violations)
    return violations
