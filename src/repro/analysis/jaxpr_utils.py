"""Shared jaxpr-walking machinery for the analysis rules.

All rules operate on *closed* jaxprs produced by ``jax.make_jaxpr``.
Sub-programs (scan/while bodies, pjit calls, custom_jvp rules, Pallas
kernel bodies) live inside equation params; ``walk_eqns`` flattens the
whole nest into one stream of ``(eqn, in_pallas)`` pairs so a rule can
either skip kernel bodies (in-kernel tiles are VMEM-resident by
construction — most data-path rules do) or descend into them.
"""

from __future__ import annotations

from typing import Iterator

import jax

PALLAS_PRIMITIVE = "pallas_call"


def _as_jaxpr(obj):
    """Unwrap ClosedJaxpr -> Jaxpr; pass Jaxpr through; else None."""
    inner = getattr(obj, "jaxpr", None)
    if inner is not None and hasattr(inner, "eqns"):
        return inner
    if hasattr(obj, "eqns"):
        return obj
    return None


def subjaxprs(eqn) -> Iterator:
    """Every sub-jaxpr stored in an equation's params (scan/pjit/cond
    bodies, custom-derivative rules, Pallas kernel bodies, ...)."""
    for param in eqn.params.values():
        for leaf in jax.tree.leaves(
                param, is_leaf=lambda x: _as_jaxpr(x) is not None):
            sub = _as_jaxpr(leaf)
            if sub is not None:
                yield sub


def walk_eqns(jaxpr, in_pallas: bool = False) -> Iterator[tuple]:
    """Yield ``(eqn, in_pallas)`` for every equation in ``jaxpr`` and all
    nested sub-jaxprs; ``in_pallas`` is True inside a pallas_call body."""
    jaxpr = _as_jaxpr(jaxpr)
    for eqn in jaxpr.eqns:
        yield eqn, in_pallas
        is_pallas = eqn.primitive.name == PALLAS_PRIMITIVE
        for sub in subjaxprs(eqn):
            yield from walk_eqns(sub, in_pallas or is_pallas)


def walk_jaxprs(jaxpr, in_pallas: bool = False) -> Iterator[tuple]:
    """Yield ``(jaxpr, in_pallas)`` for the program and every nested
    sub-jaxpr — for rules that need per-scope dataflow (producer maps)."""
    jaxpr = _as_jaxpr(jaxpr)
    yield jaxpr, in_pallas
    for eqn in jaxpr.eqns:
        is_pallas = eqn.primitive.name == PALLAS_PRIMITIVE
        for sub in subjaxprs(eqn):
            yield from walk_jaxprs(sub, in_pallas or is_pallas)


def shape_of(var) -> tuple:
    """Static shape of a jaxpr atom (``()`` for literals/abstract)."""
    aval = getattr(var, "aval", None)
    shape = getattr(aval, "shape", ())
    try:
        return tuple(int(s) for s in shape)
    except TypeError:  # dynamic/polymorphic dims: not comparable
        return ()


def dtype_of(var):
    aval = getattr(var, "aval", None)
    return getattr(aval, "dtype", None)


def producer_map(jaxpr) -> dict:
    """Map each output Var of ``jaxpr``'s equations to its defining eqn
    (one scope only — sub-jaxprs get their own map)."""
    jaxpr = _as_jaxpr(jaxpr)
    out = {}
    for eqn in jaxpr.eqns:
        for v in eqn.outvars:
            out[v] = eqn
    return out


def is_literal(var) -> bool:
    return not hasattr(var, "count")  # Literal atoms have .val, no .count


def describe_eqn(eqn) -> str:
    """Short human-readable equation summary for violation messages."""
    outs = ", ".join(
        f"{getattr(dtype_of(v), 'name', '?')}{list(shape_of(v))}"
        for v in eqn.outvars)
    return f"{eqn.primitive.name} -> {outs}"
