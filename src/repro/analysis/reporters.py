"""Text and JSON rendering of an ``analyze`` sweep.

The text form is the human CI log; the JSON form is the machine
artifact the analyze lane uploads (schema: one record per target with
its kind, applied rules, and violations)."""

from __future__ import annotations

import json


def render_text(results, verbose: bool = False) -> str:
    """One line per violating target (every target when ``verbose``),
    then a one-line summary."""
    lines = []
    n_viol = 0
    for target, violations in results:
        if violations:
            n_viol += len(violations)
            lines.append(f"FAIL {target.name}")
            for v in violations:
                loc = f"  [{v.site}]" if v.site else ""
                lines.append(f"     {v.rule}: {v.message}{loc}")
        elif verbose:
            lines.append(f"ok   {target.name}  ({', '.join(target.rules)})")
    lines.append(
        f"{len(results)} target(s) analyzed, {n_viol} violation(s)")
    return "\n".join(lines)


def render_json(results) -> str:
    records = []
    for target, violations in results:
        records.append({
            "target": target.name,
            "kind": target.kind,
            "rules": list(target.rules),
            "violations": [
                {"rule": v.rule, "message": v.message, "site": v.site}
                for v in violations
            ],
        })
    n_viol = sum(len(v) for _, v in results)
    return json.dumps({"targets": records,
                       "num_targets": len(results),
                       "num_violations": n_viol}, indent=2)
