"""The initial rule set — each rule pins one repo-level invariant.

  no-dense-silo-stack   the server never materializes / reduces an
                        (n, d, d) decompressed silo stack (PR 3's
                        guarantee, generalized to every method x
                        compressor combination)
  no-dense-roundtrip    the Pallas payload path never builds a
                        block^2-trailing-dim dense selection mask or
                        scatter round-trip (PR 4's guarantee, promoted
                        from tests/test_infra.py)
  dtype-discipline      under x64 no f64 value is silently downcast and
                        then laundered back into an f64 result (or into
                        the program output)
  no-host-sync          no io/pure/debug callback inside a jitted hot
                        path (host round-trips serialize the step)
  padding-sentinel      every drop-mode scatter fed by a payload index
                        stream remaps -1 before the scatter (jax
                        normalizes negatives to index n-1 BEFORE the
                        bounds check — unremapped padding silently
                        overwrites the last row)
  vmem-budget           every pallas_call's per-program block footprint
                        (sum of BlockSpec tiles x dtype width) fits the
                        VMEM dispatch budget — fail at trace time, not
                        as a runtime OOM

All rules are trace-only: they walk jaxprs, never execute them.
"""

from __future__ import annotations

import numpy as np

from .framework import Rule, Target, register_rule
from .jaxpr_utils import (
    PALLAS_PRIMITIVE,
    describe_eqn,
    dtype_of,
    is_literal,
    producer_map,
    shape_of,
    walk_eqns,
    walk_jaxprs,
)

_REDUCING = ("reduce_sum", "reduce_prod", "reduce_max", "reduce_min",
             "reduce_and", "reduce_or", "reduce_precision")


@register_rule
class NoDenseSiloStack(Rule):
    """No dense (n, d, d) silo stack on the server path.

    On ``aggregate`` targets (a ``Compressor.aggregate`` trace over
    stacked payloads): no equation may *emit* an (n, d, d) array at all
    — the structure-aware fast paths go straight from payload space to
    ONE dense accumulator. Dense-wire families (Identity, Natural,
    Dithering — payload already carries one slot per entry, marked
    ``wire_is_dense``) are exempted by the target builder, not here.

    On every other kind (method-step, precond): device-side (n, d, d)
    arrays are legitimate (stacked Hessian oracles, per-silo H_i
    state, per-silo diffs entering compress), so the rule instead
    flags any *reduction* of an (n, d, d) input into a (d, d) output —
    the decompress-then-mean server aggregation the payload pipeline
    exists to delete.
    """

    name = "no-dense-silo-stack"
    description = ("server aggregation stays in payload space: no "
                   "(n, d, d) decompressed stack is built or reduced")

    def check(self, jaxpr, target: Target):
        n = target.context.get("silo_axis")
        dense = tuple(target.context.get("dense_shape", ()))
        if not n or not dense:
            return []
        stack = (int(n),) + dense
        out = []
        for eqn, in_pallas in walk_eqns(jaxpr):
            if in_pallas or eqn.primitive.name == PALLAS_PRIMITIVE:
                continue
            if target.kind != "aggregate":
                if (eqn.primitive.name in _REDUCING
                        or eqn.primitive.name == "dot_general"):
                    if any(shape_of(v) == stack for v in eqn.invars
                           if not is_literal(v)) and any(
                               shape_of(v) == dense for v in eqn.outvars):
                        out.append(self.violation(
                            target,
                            f"dense reduction of the {stack} silo stack "
                            f"into {dense} — server aggregation must stay "
                            "in payload space",
                            describe_eqn(eqn)))
            else:
                for v in eqn.outvars:
                    if shape_of(v) == stack:
                        out.append(self.violation(
                            target,
                            f"materializes the dense {stack} silo stack "
                            "(decompress-then-mean path)",
                            describe_eqn(eqn)))
        return out


@register_rule
class NoDenseRoundtrip(Rule):
    """No intermediate with a block^2 trailing dim outside pallas_call
    bodies — neither the dense per-tile selection mask nor the dense
    scatter round-trip exists in the traced step (in-kernel tiles are
    VMEM-resident by construction and exempt)."""

    name = "no-dense-roundtrip"
    description = ("the payload compression path never materializes a "
                   "block^2-trailing-dim dense tile intermediate outside "
                   "kernel bodies")

    def check(self, jaxpr, target: Target):
        block = int(target.context.get("block", 0))
        # ``dense_forbidden``: an exact shape (e.g. the full (d, d) diff
        # a fused diff->select->payload kernel keeps out of HBM) that
        # must not appear as any equation output outside kernel bodies.
        # Separate from ``block`` because fused-uplink targets have
        # legitimate (d, d)-shaped *inputs* but may never rebuild the
        # dense difference as an intermediate.
        forbidden = tuple(target.context.get("dense_forbidden", ()))
        if not block and not forbidden:
            return []
        bb = block * block
        out = []
        for eqn, in_pallas in walk_eqns(jaxpr):
            if in_pallas or eqn.primitive.name == PALLAS_PRIMITIVE:
                continue
            for v in eqn.outvars:
                shape = shape_of(v)
                if block and shape and shape[-1] == bb:
                    out.append(self.violation(
                        target,
                        f"dense block^2={bb} trailing-dim intermediate "
                        "(selection mask / per-tile scatter round-trip)",
                        describe_eqn(eqn)))
                elif forbidden and shape == forbidden:
                    out.append(self.violation(
                        target,
                        f"dense {forbidden} intermediate on a fused "
                        "diff->payload path (the difference must stay "
                        "tile-resident inside the kernel)",
                        describe_eqn(eqn)))
        return out


_NARROW_FLOATS = ("float32", "float16", "bfloat16")


@register_rule
class DtypeDiscipline(Rule):
    """No silent f64 -> narrow-float downcast that re-enters an f64
    result. Under x64 the paper's accounting is double precision end to
    end; a narrowing ``convert_element_type`` is only a bug when the
    narrowed value flows back into f64 (precision laundering) or into
    the program output — narrowing used purely for *selection* (index
    computation, comparisons) is documented behavior and passes because
    the taint dies at the bool/int boundary.

    Scope: per-jaxpr dataflow (taint does not cross scan/pjit
    boundaries; the downcast and its re-entry live in the same traced
    scope in every pattern this repo contains)."""

    name = "dtype-discipline"
    description = ("no silent f64->f32 downcast on the Hessian path "
                   "re-entering an f64 result under x64")

    def check(self, jaxpr, target: Target):
        out = []
        for scope, in_pallas in walk_jaxprs(jaxpr):
            if in_pallas:
                continue
            out.extend(self._check_scope(scope, target,
                                         outermost=scope is getattr(
                                             jaxpr, "jaxpr", jaxpr)))
        return out

    def _check_scope(self, scope, target: Target, outermost: bool):
        tainted = set()
        out = []
        for eqn in scope.eqns:
            if eqn.primitive.name == PALLAS_PRIMITIVE:
                continue
            in_tainted = any(not is_literal(v) and v in tainted
                             for v in eqn.invars)
            if eqn.primitive.name == "convert_element_type":
                src = dtype_of(eqn.invars[0])
                dst = dtype_of(eqn.outvars[0])
                src_name = getattr(src, "name", "")
                dst_name = getattr(dst, "name", "")
                if src_name == "float64" and dst_name in _NARROW_FLOATS:
                    tainted.add(eqn.outvars[0])
                    continue
                if dst_name == "float64" and in_tainted:
                    out.append(self.violation(
                        target,
                        "f64 value silently downcast and converted back "
                        "to f64 (precision laundering)",
                        describe_eqn(eqn)))
                    continue
            if in_tainted:
                for v in eqn.outvars:
                    name = getattr(dtype_of(v), "name", "")
                    if name in _NARROW_FLOATS:
                        tainted.add(v)
        if outermost:
            for v in scope.outvars:
                if not is_literal(v) and v in tainted:
                    out.append(self.violation(
                        target,
                        "program output is an f64 value silently "
                        "downcast to "
                        f"{getattr(dtype_of(v), 'name', '?')}",
                        f"outvar {getattr(dtype_of(v), 'name', '?')}"
                        f"{list(shape_of(v))}"))
        return out


_CALLBACKS = ("pure_callback", "io_callback", "debug_callback",
              "outside_call")


@register_rule
class NoHostSync(Rule):
    """No host callback primitive inside a jitted hot path: every
    callback forces a device->host->device round trip that serializes
    the step (and breaks multi-host execution)."""

    name = "no-host-sync"
    description = ("no io_callback/pure_callback/debug_callback inside "
                   "jitted hot paths")

    def check(self, jaxpr, target: Target):
        out = []
        for eqn, _ in walk_eqns(jaxpr):
            if eqn.primitive.name in _CALLBACKS:
                out.append(self.violation(
                    target,
                    f"host callback `{eqn.primitive.name}` inside a "
                    "jitted hot path",
                    describe_eqn(eqn)))
        return out


def _mode_is_drop(mode) -> bool:
    return "FILL_OR_DROP" in str(mode)


class _Slicer:
    """Backward slice over index dataflow, following values across
    pjit/scan/cond scope boundaries where the mapping is positional."""

    TRANSPARENT = ("reshape", "broadcast_in_dim", "convert_element_type",
                   "squeeze", "expand_dims", "transpose", "slice", "rev",
                   "copy", "stop_gradient", "gather", "dynamic_slice")
    SAFE_SOURCES = ("iota", "top_k", "argsort", "sort", "argmax", "argmin",
                    "cumsum", "cumprod", "cummax", "cummin", "rng_bit_generator")
    SANITIZERS = ("clamp",)
    COMBINING = ("add", "sub", "mul", "div", "rem", "neg", "concatenate",
                 "pad", "select_and_scatter_add", "min")

    def __init__(self):
        self.seen = set()

    def safe(self, var, frames) -> bool:
        """frames: list of (jaxpr, parent_frames_entry) from outermost in
        — each entry is (scope_jaxpr, producing_eqn_in_parent or None).
        Returns True when ``var`` provably cannot carry an unremapped
        negative payload index into the scatter."""
        if is_literal(var):
            return True
        key = id(var)
        if key in self.seen:
            return True  # cycle/diamond: already being verified
        self.seen.add(key)

        scope, parent = frames[-1]
        if var in getattr(scope, "constvars", ()):
            return True  # trace-time constant
        if var in scope.invars:
            if parent is None:
                return False  # the traced program's own input: a raw
                # payload index stream may be negative
            outer_eqn, outer_frames = parent
            mapped = self._map_invar(scope, var, outer_eqn)
            if mapped is None:
                return True  # unmapped scope boundary: inconclusive
            return self.safe(mapped, outer_frames)

        prod = self.producers(scope).get(var)
        if prod is None:
            return True
        name = prod.primitive.name
        if name in self.SAFE_SOURCES:
            return True
        if name in self.SANITIZERS:
            return True
        if name == "max":
            # max(i, c) with a non-negative constant clamps the padding
            ops = prod.invars
            if any(is_literal(o) and np.all(np.asarray(o.val) >= 0)
                   for o in ops):
                return True
            return all(self.safe(o, frames) for o in ops)
        if name == "select_n":
            return self._select_safe(prod, frames)
        if name in self.TRANSPARENT:
            return self.safe(prod.invars[0], frames)
        if name in self.COMBINING:
            return all(self.safe(o, frames) for o in prod.invars)
        if name in ("pjit", "closed_call", "core_call", "scan", "while",
                    "cond", "custom_jvp_call", "custom_vjp_call"):
            return True  # opaque producer: inconclusive, do not flag
        if name.startswith("scatter"):
            # indices built by a scatter (payload *construction*): the
            # fill value may be -1 by design — treat as unsafe only if
            # its own inputs are unsafe is overly deep; inconclusive
            return True
        if name.startswith("random_") or "random" in name:
            return True
        return False  # unknown producer of an index stream

    def _select_safe(self, eqn, frames) -> bool:
        """A ``select_n`` guarding the index stream. jnp auto-inserts
        the negative-wrap normalization ``select(i < 0, i, i + n)`` at
        every indexing site — that pattern is TRANSPARENT (the hazard:
        -1 wraps to n-1). Any *other* select (e.g. the explicit
        ``where(i < 0, n, i)`` remap, whose negative branch does not
        derive from i) is a sanitizer."""
        pred, on_false, on_true = eqn.invars[0], eqn.invars[1], eqn.invars[2]
        scope, _ = frames[-1]
        prods = self.producers(scope)
        pred_eqn = None if is_literal(pred) else prods.get(pred)
        if pred_eqn is not None and pred_eqn.primitive.name == "lt":
            compared = pred_eqn.invars[0]
            true_eqn = None if is_literal(on_true) else prods.get(on_true)
            if (true_eqn is not None
                    and true_eqn.primitive.name == "add"
                    and any((not is_literal(o)) and o is compared
                            for o in true_eqn.invars)):
                # auto-normalization: keep slicing from the raw index
                return self.safe(compared, frames)
        return True  # a user-level remap/guard: sanitized

    def _map_invar(self, scope, var, eqn):
        """Map a sub-jaxpr invar back to the producing eqn's operand
        (positional for pjit/closed_call and scan; None elsewhere)."""
        idx = list(scope.invars).index(var)
        name = eqn.primitive.name
        if name in ("pjit", "closed_call", "core_call", "scan"):
            if idx < len(eqn.invars):
                return eqn.invars[idx]
        return None

    def producers(self, scope) -> dict:
        cache = getattr(scope, "_analysis_producers", None)
        if cache is None:
            cache = producer_map(scope)
            try:
                object.__setattr__(scope, "_analysis_producers", cache)
            except (AttributeError, TypeError):
                pass
        return cache


@register_rule
class PaddingSentinel(Rule):
    """Every drop-mode scatter whose index stream may contain ``-1``
    payload padding must remap the sentinel out of range *before* the
    scatter: jax normalizes negative indices (-1 -> n-1) ahead of the
    ``mode='drop'`` bounds check, so unremapped padding silently
    overwrites the last slot instead of being dropped. Detected
    statically: a FILL_OR_DROP scatter whose backward index slice
    reaches a program input (a payload index stream) through jnp's
    negative-wrap normalization with no sanitizing remap in between."""

    name = "padding-sentinel"
    description = ("-1 payload padding is remapped out of range before "
                   "every mode='drop' scatter")

    def check(self, jaxpr, target: Target):
        out = []
        self._walk(getattr(jaxpr, "jaxpr", jaxpr), None, out, target)
        return out

    def _walk(self, scope, parent, out, target, in_pallas=False):
        from .jaxpr_utils import _as_jaxpr, subjaxprs

        scope = _as_jaxpr(scope)
        frames_here = (parent[1] + [(scope, parent)]) if parent \
            else [(scope, None)]
        for eqn in scope.eqns:
            is_pallas = eqn.primitive.name == PALLAS_PRIMITIVE
            if (not in_pallas and not is_pallas
                    and eqn.primitive.name.startswith("scatter")
                    and _mode_is_drop(eqn.params.get("mode"))):
                idx_var = eqn.invars[1]
                if not _Slicer().safe(idx_var, frames_here):
                    out.append(self.violation(
                        target,
                        "drop-mode scatter consumes a potentially "
                        "negative payload index stream without "
                        "remapping -1 out of range first (negative "
                        "indices wrap to n-1 BEFORE the bounds check)",
                        describe_eqn(eqn)))
            for sub in subjaxprs(eqn):
                self._walk(sub, (eqn, frames_here), out, target,
                           in_pallas or is_pallas)


@register_rule
class VmemBudget(Rule):
    """Every ``pallas_call``'s per-program VMEM block footprint — the
    sum over its BlockSpecs of tile-elements x dtype width (operand
    tiles + output/accumulator tiles) — must fit the dispatch budget
    (``repro.kernels.VMEM_BUDGET_BYTES``, 8 MiB of the ~16 MiB/core
    VMEM, leaving headroom for scratch and double buffering). Checked
    statically from the traced grid mapping, so an over-budget kernel
    config fails analysis instead of OOMing on device."""

    name = "vmem-budget"
    description = ("pallas_call BlockSpec footprints fit the 8 MiB VMEM "
                   "dispatch budget at trace time")

    def check(self, jaxpr, target: Target):
        from ..kernels import VMEM_BUDGET_BYTES

        budget = int(target.context.get("vmem_budget", VMEM_BUDGET_BYTES))
        out = []
        for eqn, _ in walk_eqns(jaxpr):
            if eqn.primitive.name != PALLAS_PRIMITIVE:
                continue
            gm = eqn.params.get("grid_mapping")
            if gm is None:
                continue
            total = 0
            parts = []
            for bm in gm.block_mappings:
                elems = 1
                for s in bm.block_shape:
                    elems *= int(s) if isinstance(s, (int, np.integer)) \
                        else 1
                dtype = np.dtype(bm.array_shape_dtype.dtype)
                total += elems * dtype.itemsize
                parts.append(
                    f"{tuple(bm.block_shape)}x{dtype.name}")
            if total > budget:
                kname = getattr(eqn.params.get("name_and_src_info"),
                                "name", "pallas_call")
                out.append(self.violation(
                    target,
                    f"kernel `{kname}` blocks {' + '.join(parts)} = "
                    f"{total / 2**20:.1f} MiB exceed the "
                    f"{budget / 2**20:.0f} MiB VMEM dispatch budget",
                    describe_eqn(eqn)))
        return out
