"""Source-level (AST) rules — same registry and reporting surface as
the jaxpr rules, but the target traces to a file path instead of a
closed jaxpr.

  no-deprecated-accessor   keeps the deprecated wire-cost quartet
                           (``comp.bits(shape)``, ``comp.spec(...).bits``,
                           ``payload_bits(...)``, ``payload.bits(...)``)
                           out of ``src/`` — internal code goes through
                           ``repro.wire.wire_cost``; the aliases stay
                           only for external users. Also flags the old
                           hand-composed participation weighting
                           ``.aggregate(scale_payload(...), ...)`` —
                           weights are an ``aggregate`` kwarg now.
"""

from __future__ import annotations

import ast

from .framework import Rule, Target, register_rule


@register_rule
class NoDeprecatedAccessor(Rule):
    """Flag internal use of the deprecated wire-cost quartet.

    Patterns (exactly the quartet, nothing looser — ``cell.bits`` on a
    record cell is a different, live field and must not trip this):

      * a *call* of a ``.bits`` attribute — ``comp.bits((d, d))`` and
        ``payload.bits(index_coding=...)``
      * ``.bits`` read off a ``.spec(...)`` call — ``comp.spec(s).bits``
      * any Load of the name ``payload_bits`` (re-export ImportFrom
        aliases are ast.alias nodes, not Names, so ``__init__``
        re-exports pass)
      * ``.aggregate(...)`` whose first argument is a
        ``scale_payload(...)`` call — the pre-redesign participation
        weighting; pass ``weights=`` to ``aggregate`` instead (the
        standalone ``scale_payload`` stays fine for payload-level uses
        that never reach an aggregate)

    The defining modules (``core/compressors.py``, ``wire/report.py``)
    are excluded by the target builder, not here.
    """

    name = "no-deprecated-accessor"
    description = ("internal code uses wire_cost, not the deprecated "
                   "bits/spec().bits/payload_bits/payload.bits quartet; "
                   "participation weighting goes through "
                   "aggregate(weights=), not aggregate(scale_payload())")
    kinds = ("source",)

    def check(self, path, target: Target):
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        tree = ast.parse(src, filename=str(path))
        out = []

        def flag(node, what):
            out.append(self.violation(
                target,
                f"deprecated wire-cost accessor `{what}` — use "
                "repro.wire.wire_cost (WireReport) instead",
                f"{path}:{node.lineno}"))

        def is_scale_payload(call) -> bool:
            if not isinstance(call, ast.Call):
                return False
            f = call.func
            return ((isinstance(f, ast.Name) and f.id == "scale_payload")
                    or (isinstance(f, ast.Attribute)
                        and f.attr == "scale_payload"))

        for node in ast.walk(tree):
            if isinstance(node, ast.Call):
                fn = node.func
                if isinstance(fn, ast.Attribute) and fn.attr == "bits":
                    flag(node, ".bits(...)")
                elif (isinstance(fn, ast.Attribute)
                      and fn.attr == "aggregate"
                      and node.args and is_scale_payload(node.args[0])):
                    out.append(self.violation(
                        target,
                        "hand-composed `.aggregate(scale_payload(...))` "
                        "— pass the per-silo weights via "
                        "aggregate(..., weights=w) instead",
                        f"{path}:{node.lineno}"))
            elif isinstance(node, ast.Attribute) and node.attr == "bits":
                val = node.value
                if (isinstance(val, ast.Call)
                        and isinstance(val.func, ast.Attribute)
                        and val.func.attr == "spec"):
                    flag(node, ".spec(...).bits")
            elif (isinstance(node, ast.Name)
                  and node.id == "payload_bits"
                  and isinstance(node.ctx, ast.Load)):
                flag(node, "payload_bits")
        return out
