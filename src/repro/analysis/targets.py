"""Target enumeration: turn the engine's registries into the analyzable
surface.

Four jaxpr-traced families plus one source-level family:

  method:<name>[<comp>]   one ``step`` of every registered method, for
                          every registered compressor family (Newton
                          references once, with their dense wire)
  aggregate:<comp>        ``Compressor.aggregate`` over a stacked
                          payload struct (``jax.eval_shape`` of the
                          vmapped compress — zero FLOPs)
  kernel:<pkg>:<op>       every Pallas kernel package's
                          ``analysis_targets()`` configs (bodies
                          forced, trace-only)
  precond:update[...]     the fednl_precond training step on its pinned
                          TPU path (single-tensor and cross-silo)
  train-step:fednl[...]   the FULL fednl train step (real reduced arch,
                          curvature-observation phase, lax.cond refresh)
  source:<path>           every module under ``src/repro`` (AST rules)

Everything is lazy: enumerating targets costs nothing; ``analyze``
traces each exactly once and runs its rules.
"""

from __future__ import annotations

import pathlib
from typing import Iterator, Optional, Sequence

import jax
import jax.numpy as jnp

from .framework import Target, Violation, get_rule

_N_SILOS = 3
_DIM = 16

# Per-method constructor params (harvested from the engine tests): the
# smallest config each factory accepts. "fednl-cohort" needs a
# ``CohortSpec`` instance — constructed lazily in ``_method_targets``
# so enumerating targets stays import-light.
_METHOD_PARAMS = {
    "fednl-pp": {"tau": 2},
    "fednl-cr": {"l_star": 1.0},
    "fednl-bc": {"model_compressor": ("topk", 5), "p": 0.9, "option": 1,
                 "mu": 1e-3},
    "fednl-ppbc": {"model_compressor": ("topk", 5), "tau": 2},
}

# Representative level per compressor family (the factory knob).
_COMPRESSOR_LEVELS = {
    "topk": 5, "topksym": 5, "randk": 5, "rankr": 1, "powersgd": 1,
    "blocktopk": 4, "blocktopkthreshold": 4, "dithering": 4,
    "natural": 0.5, "identity": None, "zero": None,
}

_KERNEL_PACKAGES = ("block_topk", "scatter_accum", "hess_update",
                    "tiled_matmul", "flash_attention", "tuning")

_JAXPR_RULES = ("no-host-sync", "padding-sentinel")

# Modules that DEFINE the deprecated wire-cost accessors (and their
# WireReport implementation) — excluded from the source sweep.
_SOURCE_ALLOWLIST = ("core/compressors.py", "wire/report.py")


def _float():
    return jnp.result_type(float)


def _oracles(n: int, d: int):
    """Synthetic quadratic oracles in the paper's federated form: silo i
    holds f_i(x) = c_i/2 ||x||^2, so grads stack to (n, d) and Hessians
    to (n, d, d) — enough structure for every method to trace."""
    from ..engine.method import Oracles

    coef = jnp.arange(1, n + 1, dtype=_float()) / n

    def value(x):
        return 0.5 * jnp.mean(coef) * jnp.sum(x * x)

    def grad(x):
        return coef[:, None] * x[None, :]

    def hess(x):
        eye = jnp.eye(d, dtype=x.dtype)
        return coef[:, None, None] * eye[None]

    return Oracles(value, grad, hess)


def _compressor_families():
    """(name, factory) per unique registered family — spelling aliases
    share a factory object and are reported once, under the first
    alphabetical name."""
    from ..core.compressors import registered_compressors

    reg = registered_compressors()
    seen = {}
    for name in sorted(reg, key=lambda n: (n not in _COMPRESSOR_LEVELS, n)):
        fac = reg[name]
        if id(fac) not in seen:
            seen[id(fac)] = name
    return [(name, reg[name]) for name in sorted(seen.values())]


def _make_comp(name):
    from ..core.compressors import make_compressor

    return make_compressor(name, _COMPRESSOR_LEVELS.get(name, 5))


def _method_targets() -> Iterator[Target]:
    from ..engine.method import make_method, registered_methods

    n, d = _N_SILOS, _DIM
    orc = _oracles(n, d)
    x0 = jax.ShapeDtypeStruct((d,), _float())

    def one(mname, cname, comp):
        params = dict(_METHOD_PARAMS.get(mname, {}))
        if mname == "fednl-cohort":
            from ..core.cohort import CohortSpec

            params["cohort"] = CohortSpec(cohort=2, population=n)
        if mname == "ns":
            params["h_fixed"] = jnp.eye(d, dtype=_float())
        method = make_method(mname, orc, comp, **params)

        def trace():
            state = jax.eval_shape(lambda x: method.init(x, n), x0)
            return jax.make_jaxpr(method.step)(state)

        rules = _JAXPR_RULES + ("dtype-discipline",)
        if comp is not None and not comp.wire_is_dense:
            rules = rules + ("no-dense-silo-stack",)
        label = f"method:{mname}[{cname}]" if comp is not None \
            else f"method:{mname}"
        return Target(name=label, kind="method-step", trace=trace,
                      rules=rules,
                      context={"silo_axis": n, "dense_shape": (d, d)})

    families = _compressor_families()
    for mname in sorted(registered_methods()):
        if mname in ("newton", "n0", "n0-ls", "ns"):
            # Newton references: no compressor, dense wire by definition
            yield one(mname, "", None)
        else:
            for cname, _fac in families:
                yield one(mname, cname, _make_comp(cname))


def _aggregate_targets() -> Iterator[Target]:
    n, shape = _N_SILOS, (_DIM, _DIM)
    for cname, _fac in _compressor_families():
        comp = _make_comp(cname)

        def trace(comp=comp):
            m = jax.ShapeDtypeStruct((n,) + shape, _float())
            keys = jax.ShapeDtypeStruct((n, 2), jnp.uint32)
            pay = jax.eval_shape(jax.vmap(comp.compress), m, keys)
            return jax.make_jaxpr(lambda p: comp.aggregate(p, shape))(pay)

        rules = _JAXPR_RULES
        if not comp.wire_is_dense:
            rules = rules + ("no-dense-silo-stack",)
        yield Target(name=f"aggregate:{cname}", kind="aggregate",
                     trace=trace, rules=rules,
                     context={"silo_axis": n, "dense_shape": shape})

    # The cross-device server paths. ``streamed-slab`` is the device-
    # side jaxpr the host streaming loop replays per silo slab —
    # exactly what runs when n * k outgrows the VMEM budget — and
    # ``sharded-window`` is the shard_map'd row-window scatter behind
    # the mesh-sharded accumulator. Both must keep the payload -> ONE
    # dense accumulator discipline (no (n, d, d) stack) AND fit every
    # pallas_call inside the VMEM dispatch budget, so they carry both
    # rules on top of the baseline set.
    path_rules = _JAXPR_RULES + ("no-dense-silo-stack", "vmem-budget")
    path_ctx = {"silo_axis": n, "dense_shape": shape}

    def trace_streamed():
        from ..kernels.scatter_accum import streamed_slab_update

        acc = jax.ShapeDtypeStruct(shape, _float())
        vals = jax.ShapeDtypeStruct((n, 5), _float())
        idx = jax.ShapeDtypeStruct((n, 5), jnp.int32)
        return jax.make_jaxpr(
            lambda a, v, i: streamed_slab_update(
                a, v, i, shape, interpret=True, symmetric=True))(
                    acc, vals, idx)

    def trace_sharded():
        import numpy as np
        from jax.sharding import Mesh

        from ..kernels.scatter_accum import sharded_scatter_accumulate

        mesh = Mesh(np.asarray(jax.devices()[:1]).reshape(1), ("data",))
        vals = jax.ShapeDtypeStruct((n, 5), _float())
        idx = jax.ShapeDtypeStruct((n, 5), jnp.int32)
        return jax.make_jaxpr(
            lambda v, i: sharded_scatter_accumulate(
                v, i, shape, mesh, use_pallas=True, interpret=True,
                symmetric=True))(vals, idx)

    yield Target(name="aggregate:streamed-slab", kind="aggregate",
                 trace=trace_streamed, rules=path_rules,
                 context=dict(path_ctx))
    yield Target(name="aggregate:sharded-window", kind="aggregate",
                 trace=trace_sharded, rules=path_rules,
                 context=dict(path_ctx))


def _kernel_targets() -> Iterator[Target]:
    import importlib

    for pkg in _KERNEL_PACKAGES:
        mod = importlib.import_module(f"repro.kernels.{pkg}")
        for spec in mod.analysis_targets():
            rules = _JAXPR_RULES + ("vmem-budget",)
            if "block" in spec.get("context", {}):
                rules = rules + ("no-dense-roundtrip",)
            yield Target(name=f"kernel:{pkg}:{spec['name']}",
                         kind="kernel", trace=spec["trace"], rules=rules,
                         context=dict(spec.get("context", {})))


def _precond_targets() -> Iterator[Target]:
    """The fednl_precond step on its pinned TPU path — deliberately
    mixed-precision (f32 curvature state by design), so the dtype rule
    does not apply; the dense-free payload path and VMEM budget do."""
    from ..second_order.fednl_precond import FedNLPrecondOptimizer

    d, block = 256, 128
    opt = FedNLPrecondOptimizer(lr=0.1, k_per_block=32, block=block,
                                use_pallas=True)
    params = {"w": jax.ShapeDtypeStruct((d, d), jnp.float32)}
    grads = {"w": jax.ShapeDtypeStruct((d, d), jnp.float32)}
    rules = _JAXPR_RULES + ("no-dense-roundtrip", "vmem-budget",
                            "no-dense-silo-stack")
    ctx = {"block": block, "silo_axis": _N_SILOS,
           "dense_shape": (d, d)}

    def trace_single():
        state = jax.eval_shape(opt.init, params)
        return jax.make_jaxpr(
            lambda g, s, p: opt.update(g, s, p))(grads, state, params)

    def trace_silo():
        state = jax.eval_shape(opt.init, params)
        obs = {"w": jax.ShapeDtypeStruct((_N_SILOS, d, d), jnp.float32)}
        return jax.make_jaxpr(
            lambda g, s, p, o: opt.update(g, s, p, observations=o))(
                grads, state, params, obs)

    yield Target(name="precond:update[single]", kind="precond",
                 trace=trace_single, rules=rules, context=dict(ctx))
    yield Target(name="precond:update[silo]", kind="precond",
                 trace=trace_silo, rules=rules, context=dict(ctx))


def _train_step_targets() -> Iterator[Target]:
    """The fednl train step END TO END on its pinned TPU payload path:
    a reduced real architecture, the curvature-observation phase
    (per-silo grads under lax.scan, fused diff payloads, payload-space
    mean) behind the lax.cond refresh gate, and the preconditioned
    update — trace-only, so the data-path invariants are mechanically
    enforced on the exact graph ``launch/train.py`` compiles. Like the
    precond targets this path is deliberately mixed-precision (f32
    curvature state over bf16 params), so the dtype rule's f64 ban
    still applies cleanly."""
    from ..configs import get_config
    from ..launch.steps import make_optimizer, make_train_step
    from ..models import build_model

    block, n_silos = 128, 2
    rules = _JAXPR_RULES + ("no-dense-roundtrip", "dtype-discipline",
                            "vmem-budget", "no-dense-silo-stack")

    def one(name, hvp, curvature):
        cfg = get_config("qwen2-0.5b", smoke=True)
        model = build_model(cfg, use_remat=True)
        opt = make_optimizer("fednl", 1e-3, k_per_block=32, block=block,
                             curvature=curvature, use_pallas=True)
        step = make_train_step(model, opt, refresh_every=4,
                               n_silos=n_silos, hvp=hvp)

        def trace():
            b, t = 4, 32
            params = jax.eval_shape(
                model.init_params, jax.ShapeDtypeStruct((2,), jnp.uint32))
            state = jax.eval_shape(opt.init, params)
            batch = {"tokens": jax.ShapeDtypeStruct((b, t), jnp.int32),
                     "targets": jax.ShapeDtypeStruct((b, t), jnp.int32)}
            return jax.make_jaxpr(step)(params, state, batch)

        ctx = {"block": block, "silo_axis": n_silos}
        return Target(name=name, kind="train-step", trace=trace,
                      rules=rules, context=ctx)

    yield one("train-step:fednl[fisher]", False, "fisher")
    yield one("train-step:fednl[hvp]", True, "hutchinson")


def _source_targets() -> Iterator[Target]:
    root = pathlib.Path(__file__).resolve().parents[1]  # src/repro
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root).as_posix()
        if rel in _SOURCE_ALLOWLIST or rel.startswith("analysis/"):
            continue
        yield Target(name=f"source:repro/{rel}", kind="source",
                     trace=lambda p=path: p,
                     rules=("no-deprecated-accessor",), context={})


_KIND_BUILDERS = {
    "method-step": _method_targets,
    "aggregate": _aggregate_targets,
    "kernel": _kernel_targets,
    "precond": _precond_targets,
    "train-step": _train_step_targets,
    "source": _source_targets,
}


def iter_targets(kinds: Optional[Sequence[str]] = None) -> list:
    """Enumerate all analyzable targets (lazy traces — free to list)."""
    out = []
    for kind, builder in _KIND_BUILDERS.items():
        if kinds is not None and kind not in kinds:
            continue
        out.extend(builder())
    return out


def analyze(rules: Optional[Sequence[str]] = None,
            targets: Optional[Sequence[str]] = None,
            kinds: Optional[Sequence[str]] = None) -> list:
    """Run the sweep: returns ``[(target, [violations]), ...]`` over
    every enumerated target (filtered by rule name / target-name
    substring / kind). A target whose trace itself fails contributes an
    ``analysis-error`` violation — a broken registry entry must fail
    the lane loudly, not vanish from it."""
    results = []
    for t in iter_targets(kinds):
        if targets is not None and not any(s in t.name for s in targets):
            continue
        active = [r for r in t.rules if rules is None or r in rules]
        if not active:
            continue
        try:
            traced = t.trace()
            found = []
            for rname in active:
                rule = get_rule(rname)
                if rule.kinds and t.kind not in rule.kinds:
                    continue
                found.extend(rule.check(traced, t))
        except Exception as e:  # noqa: BLE001 — reported, not swallowed
            found = [Violation(rule="analysis-error", target=t.name,
                               message=f"{type(e).__name__}: {e}")]
        results.append((t, found))
    return results
