"""Minimal dependency-free checkpointing: pytree -> .npz + structure.

Arrays are gathered to host (fine at example scale; a production TPU
deployment would swap in per-shard async writes — the API is the same).
"""

from __future__ import annotations

import json
import os
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _to_numpy(leaf) -> np.ndarray:
    arr = np.asarray(leaf)
    if arr.dtype.kind == "V":  # bfloat16 etc. — no native numpy dtype
        arr = np.asarray(jnp.asarray(leaf).astype(jnp.float32))
    return arr


def save(path: str, tree: Any, step: int = 0) -> None:
    os.makedirs(path, exist_ok=True)
    leaves, treedef = jax.tree.flatten(tree)
    arrays = [_to_numpy(l) for l in leaves]
    np.savez(os.path.join(path, "arrays.npz"),
             **{f"leaf_{i}": a for i, a in enumerate(arrays)})
    with open(os.path.join(path, "meta.json"), "w") as f:
        json.dump({"treedef": str(treedef), "num_leaves": len(leaves),
                   "step": step}, f)


def restore(path: str, like: Any) -> tuple[Any, int]:
    """Restore into the structure of ``like`` (shape/dtype template)."""
    data = np.load(os.path.join(path, "arrays.npz"))
    with open(os.path.join(path, "meta.json")) as f:
        meta = json.load(f)
    leaves_like, treedef = jax.tree.flatten(like)
    assert meta["num_leaves"] == len(leaves_like), "structure mismatch"
    leaves = [jnp.asarray(data[f"leaf_{i}"]).astype(l.dtype)
              for i, l in enumerate(leaves_like)]
    return jax.tree.unflatten(treedef, leaves), meta["step"]
