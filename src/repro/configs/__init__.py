"""Architecture registry: one module per assigned architecture.

``get_config(name)`` returns the exact published configuration;
``get_config(name, smoke=True)`` the reduced same-family variant used by
the CPU smoke tests. ``ARCHS`` lists every selectable ``--arch`` id.
"""

from __future__ import annotations

import importlib

ARCHS = [
    "jamba-1.5-large-398b",
    "starcoder2-15b",
    "whisper-tiny",
    "minicpm3-4b",
    "starcoder2-3b",
    "granite-moe-1b-a400m",
    "grok-1-314b",
    "xlstm-350m",
    "llava-next-34b",
    "qwen2-0.5b",
    # the paper's own workload (logistic regression) is not an LM arch;
    # it is exposed via configs.fednl_logreg helpers instead.
]

_MODULES = {a: a.replace("-", "_").replace(".", "_") for a in ARCHS}


def get_config(name: str, smoke: bool = False):
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choose from {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    cfg = mod.CONFIG
    return cfg.reduced() if smoke else cfg
