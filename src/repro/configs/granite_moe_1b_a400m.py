"""Granite 3.0 1B-A400M base [hf:ibm-granite/granite-3.0-1b-a400m-base].

24 layers, d_model 1024, 16 heads (GQA kv=8), expert d_ff 512,
vocab 49155; MoE with 32 experts, top-8.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    kv_heads=8,
    d_ff=512,
    vocab=49155,
    attn_type="gqa",
    rope=True,
    mlp_type="swiglu",
    moe=MoEConfig(num_experts=32, top_k=8),
    norm="rmsnorm",
    tie_embeddings=True,
    source="[hf:ibm-granite/granite-3.0-1b-a400m-base]",
)
