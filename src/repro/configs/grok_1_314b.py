"""Grok-1 (314B) [hf:xai-org/grok-1].

64 layers, d_model 6144, 48 heads (GQA kv=8), expert d_ff 32768,
vocab 131072; MoE with 8 experts, top-2.
"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="grok-1-314b",
    family="moe",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    kv_heads=8,
    d_ff=32768,
    vocab=131072,
    attn_type="gqa",
    rope=True,
    mlp_type="gelu",
    moe=MoEConfig(num_experts=8, top_k=2),
    norm="rmsnorm",
    source="[hf:xai-org/grok-1]",
)
