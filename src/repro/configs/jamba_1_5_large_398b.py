"""Jamba 1.5 Large (398B total / ~94B active) [arXiv:2403.19887, 2408.12570].

72 layers, d_model 8192, 64 heads (GQA kv=8), d_ff 24576, vocab 65536;
Mamba : attention = 7 : 1 interleave (1 attention layer per period of 8);
MoE with 16 experts, top-2, on every other layer.
"""

from repro.models.config import MambaConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    kv_heads=8,
    d_ff=24576,
    vocab=65536,
    attn_type="gqa",
    rope=False,                    # Jamba uses no positional encoding in attn
    mlp_type="swiglu",
    moe=MoEConfig(num_experts=16, top_k=2),
    moe_every=2,
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    attn_every=8,
    norm="rmsnorm",
    source="[arXiv:2403.19887]",
)
