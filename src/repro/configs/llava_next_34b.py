"""LLaVA-NeXT 34B backbone [hf:llava-hf/llava-v1.6-mistral-7b-hf, 34B variant].

60 layers, d_model 7168, 56 heads (GQA kv=8), d_ff 20480, vocab 64000.
The vision tower + projector are stubs by the brief's carve-out:
input_specs provides precomputed patch embeddings. anyres tiling is
represented by the patch count (base 576 + 4 tiles x 576 = 2880).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    kv_heads=8,
    d_ff=20480,
    vocab=64000,
    attn_type="gqa",
    rope=True,
    mlp_type="swiglu",
    vision_tokens=2880,            # anyres: 576 base + 4x576 tiles
    norm="rmsnorm",
    source="[hf:llava-hf/llava-v1.6-mistral-7b-hf]",
)
