"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B].

62 layers, d_model 2560, 40 heads, d_ff 6400, vocab 73448; MLA attention
(q_lora 768, kv_lora 256, rope dim 32, nope dim 64, v dim 64 per the
model card) — the latent KV cache is the arch's distinguishing feature.
"""

from repro.models.config import MLAConfig, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    family="dense",
    n_layers=62,
    d_model=2560,
    n_heads=40,
    kv_heads=40,
    d_ff=6400,
    vocab=73448,
    attn_type="mla",
    mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                  qk_rope_dim=32, v_head_dim=64),
    rope=True,
    mlp_type="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="[hf:openbmb/MiniCPM3-4B]",
)
