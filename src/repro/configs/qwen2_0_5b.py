"""Qwen2-0.5B [arXiv:2407.10671].

24 layers, d_model 896, 14 heads (GQA kv=2), d_ff 4864, vocab 151936;
GQA with QKV bias, RoPE, tied embeddings.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-0.5b",
    family="dense",
    n_layers=24,
    d_model=896,
    n_heads=14,
    kv_heads=2,
    d_ff=4864,
    vocab=151936,
    attn_type="gqa",
    rope=True,
    qkv_bias=True,
    mlp_type="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    source="[arXiv:2407.10671]",
)
