"""StarCoder2-15B [arXiv:2402.19173].

40 layers, d_model 6144, 48 heads (GQA kv=4), d_ff 24576, vocab 49152;
GQA + RoPE, sliding-window attention (4096) — which is what lets the
long_500k decode shape run with a windowed cache.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    kv_heads=4,
    d_ff=24576,
    vocab=49152,
    attn_type="gqa",
    rope=True,
    sliding_window=4096,
    mlp_type="gelu",               # StarCoder2 uses a plain GELU MLP (4x)
    norm="layernorm",
    source="[arXiv:2402.19173]",
)
