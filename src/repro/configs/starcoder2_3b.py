"""StarCoder2-3B [arXiv:2402.19173].

30 layers, d_model 3072, 24 heads (GQA kv=2), d_ff 12288, vocab 49152;
GQA + RoPE + sliding window 4096.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    n_layers=30,
    d_model=3072,
    n_heads=24,
    kv_heads=2,
    d_ff=12288,
    vocab=49152,
    attn_type="gqa",
    rope=True,
    sliding_window=4096,
    mlp_type="gelu",
    norm="layernorm",
    source="[arXiv:2402.19173]",
)
