"""Whisper tiny [arXiv:2212.04356].

4 encoder + 4 decoder layers, d_model 384, 6 heads, d_ff 1536,
vocab 51865. The mel+conv audio frontend is a stub by the brief's
carve-out: input_specs provides (B, 1500, 384) frame embeddings.
Sinusoidal positions (any length), full attention.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,
    enc_layers=4,
    enc_seq=1500,
    d_model=384,
    n_heads=6,
    kv_heads=6,
    d_ff=1536,
    vocab=51865,
    attn_type="gqa",
    rope=False,                    # sinusoidal positions instead
    mlp_type="gelu",
    norm="layernorm",
    source="[arXiv:2212.04356]",
)
