"""xLSTM-350M [arXiv:2405.04517].

24 layers, d_model 1024, 4 heads, vocab 50304, d_ff 0 (the xLSTM block
carries its own projections); mLSTM : sLSTM = 7 : 1. Recurrent state
decode — runs the long_500k shape with O(1) per-token state.
"""

from repro.models.config import ModelConfig, XLSTMConfig

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    kv_heads=4,
    d_ff=0,
    vocab=50304,
    rope=False,
    xlstm=XLSTMConfig(slstm_every=8, chunk=256),
    norm="rmsnorm",
    source="[arXiv:2405.04517]",
)
