"""FedNL core: the paper's algorithms, faithfully, in JAX."""

from .compressors import (
    BlockSparsePayload,
    BlockTopK,
    BlockTopKThreshold,
    Compressor,
    CompSpec,
    DensePayload,
    DitheredPayload,
    Identity,
    LowRankPayload,
    NaturalSparsification,
    PowerSGD,
    RandK,
    RandomDithering,
    RankR,
    SparsePayload,
    TopK,
    Zero,
    ab_constants,
    alpha_for,
    available_compressors,
    make_compressor,
    payload_bits,
    register_compressor,
    scale_payload,
)
from .cohort import (
    CohortFedNLPP,
    CohortFedNLPPState,
    CohortSpec,
    sample_cohort,
    staleness_weights,
)
from .extensions import FedNLPPBC, StochasticFedNL
from .fednl import FedNL, FedNLState
from .fednl_bc import FedNLBC, FedNLBCState
from .fednl_cr import FedNLCR
from .fednl_ls import FedNLLS
from .fednl_pp import FedNLPP, FedNLPPState
from .linalg import frob_norm, project_psd, solve_cubic_subproblem
from .newton import fixed_hessian_run, n0_ls_run, newton_run
from .objectives import (
    LogRegData,
    batch_grad,
    batch_hess,
    batch_value,
    global_grad,
    global_hess,
    global_value,
    lipschitz_constants,
)

#: wire-cost names re-exported lazily: ``repro.wire`` imports this
#: package's ``compressors`` submodule, so a top-level ``from ..wire
#: import ...`` here would be a cycle. Module __getattr__ defers the
#: import until first access.
_WIRE_NAMES = ("WireReport", "wire_cost")


def __getattr__(name):
    if name in _WIRE_NAMES:
        from .. import wire

        return getattr(wire, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
