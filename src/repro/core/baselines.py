"""First-order and Newton-type baselines the paper compares against.

GD, GD-LS          — vanilla gradient descent (theoretical 1/L step) and
                     with backtracking line search.
DIANA              — compressed gradient differences
                     [Mishchenko et al. 2019]; theoretical stepsizes.
ADIANA             — accelerated DIANA [Li et al. 2020b]; theoretical
                     parameter template (strongly convex case).
DINGO              — distributed Newton-type method for gradient-norm
                     optimization [Crane & Roosta 2019]; three-case update
                     + backtracking on ||grad||^2; bits counted both
                     directions as the paper does.
NL1                — Newton Learn for GLMs [Islamov et al. 2021]:
                     learns per-data-point phi'' coefficients with Rand-K,
                     reveals the touched data points (the privacy issue
                     FedNL removes). Requires the GLM structure (eq. 2).
DORE               — double-residual bidirectional compression
                     [Liu et al. 2020] (vs FedNL-BC).
Artemis            — bidirectional compression + partial participation
                     [Philippenko & Dieuleveut 2021] (vs FedNL-PP).

All are implemented over the same stacked per-silo oracle interface as
FedNL and report analytic bits per round.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .compressors import FLOAT_BITS, INDEX_BITS, Compressor
from .newton import backtracking


# ---------------------------------------------------------------------------
# Gradient descent
# ---------------------------------------------------------------------------


def gd_run(x0, grad_fn, lr: float, num_rounds: int):
    def body(x, _):
        xn = x - lr * jnp.mean(grad_fn(x), axis=0)
        return xn, xn

    final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
    return final, jnp.concatenate([x0[None], xs], axis=0)


def gd_ls_run(x0, value_fn, grad_fn, num_rounds: int, c: float = 0.5,
              gamma: float = 0.5, t0: float = 1.0):
    def body(x, _):
        g = jnp.mean(grad_fn(x), axis=0)
        d_dir = -g
        t = backtracking(value_fn, x, d_dir, g, c=c, gamma=gamma) * t0
        xn = x + t * d_dir
        return xn, xn

    final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
    return final, jnp.concatenate([x0[None], xs], axis=0)


def gd_bits_per_round(d: int) -> int:
    return d * FLOAT_BITS


# ---------------------------------------------------------------------------
# DIANA
# ---------------------------------------------------------------------------


class DianaState(NamedTuple):
    x: jax.Array
    h_i: jax.Array  # (n, d) gradient shifts
    key: jax.Array


class Diana:
    """x^{k+1} = x^k - gamma (h^k + mean_i C(grad_i - h_i)); h_i += alpha C(.).

    Theoretical: alpha = 1/(1+omega); gamma = 1/(L (1 + 6 omega / n)).
    """

    def __init__(self, grad_fn, comp: Compressor, smooth_l: float, n: int,
                 omega: float):
        self.grad_fn = grad_fn
        self.comp = comp
        self.alpha = 1.0 / (1.0 + omega)
        self.gamma = 1.0 / (smooth_l * (1.0 + 6.0 * omega / n))

    def init(self, x0, n, seed: int = 0) -> DianaState:
        d = x0.shape[0]
        return DianaState(x0, jnp.zeros((n, d), x0.dtype), jax.random.PRNGKey(seed))

    def step(self, state: DianaState) -> DianaState:
        n = state.h_i.shape[0]
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, n)
        grads = self.grad_fn(state.x)
        delta = jax.vmap(self.comp)(grads - state.h_i, keys)
        g_hat = jnp.mean(state.h_i + delta, axis=0)
        return DianaState(
            x=state.x - self.gamma * g_hat,
            h_i=state.h_i + self.alpha * delta,
            key=key,
        )

    def bits_per_round(self, d: int) -> int:
        from ..wire.report import wire_cost

        return wire_cost(self.comp, (d,), encoded=False).analytic_bits

    def run(self, x0, n, num_rounds, seed: int = 0):
        state = self.init(x0, n, seed=seed)

        def body(state, _):
            new = self.step(state)
            return new, new.x

        final, xs = jax.lax.scan(body, state, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], xs], axis=0)


# ---------------------------------------------------------------------------
# ADIANA
# ---------------------------------------------------------------------------


class AdianaState(NamedTuple):
    x: jax.Array
    y: jax.Array
    z: jax.Array
    w: jax.Array
    h_i: jax.Array
    key: jax.Array


class Adiana:
    """Accelerated DIANA (Li et al. 2020b, Alg. 2, strongly convex setting).

    Per round: x = th1 z + th2 w + (1-th1-th2) y;
    g = h + mean C(grad_i(x) - h_i); y+ = x - eta g;
    z+ = (z + gamma mu x - gamma g) / (1 + gamma mu);
    shifts learn the anchor: h_i += alpha C(grad_i(w) - h_i);
    w+ = y with prob p (loopless anchor refresh).

    Parameters follow the paper's Theorem (up to absolute constants):
    alpha = 1/(1+om), p = alpha,
    eta = min(1/(2 L (1 + 2 om/n)), n/(64 om L)) (om>0),
    th2 = 1/2, th1 = min(1/4, sqrt(eta mu / p)),
    gamma = eta / (2 (th1 + eta mu)), beta folded into the z-update.
    """

    def __init__(self, grad_fn, comp: Compressor, smooth_l: float, mu: float,
                 n: int, omega: float):
        self.grad_fn = grad_fn
        self.comp = comp
        om = max(omega, 1e-12)
        self.alpha = 1.0 / (1.0 + om)
        self.p = self.alpha
        self.eta = min(1.0 / (2.0 * smooth_l * (1.0 + 2.0 * om / n)),
                       n / (64.0 * om * smooth_l) if omega > 0 else jnp.inf)
        self.th2 = 0.5
        self.th1 = min(0.25, float(jnp.sqrt(self.eta * mu / self.p)))
        self.gamma = self.eta / (2.0 * (self.th1 + self.eta * mu))
        self.mu = mu

    def init(self, x0, n, seed: int = 0) -> AdianaState:
        d = x0.shape[0]
        return AdianaState(x0, x0, x0, x0, jnp.zeros((n, d), x0.dtype),
                           jax.random.PRNGKey(seed))

    def step(self, state: AdianaState) -> AdianaState:
        n = state.h_i.shape[0]
        key, k1, k2, k3 = jax.random.split(state.key, 4)
        x = self.th1 * state.z + self.th2 * state.w \
            + (1.0 - self.th1 - self.th2) * state.y

        keys = jax.random.split(k1, n)
        grads_x = self.grad_fn(x)
        delta = jax.vmap(self.comp)(grads_x - state.h_i, keys)
        g = jnp.mean(state.h_i + delta, axis=0)

        y_new = x - self.eta * g
        z_new = (state.z + self.gamma * self.mu * x - self.gamma * g) \
            / (1.0 + self.gamma * self.mu)

        keys_w = jax.random.split(k2, n)
        grads_w = self.grad_fn(state.w)
        delta_w = jax.vmap(self.comp)(grads_w - state.h_i, keys_w)
        h_new = state.h_i + self.alpha * delta_w

        refresh = jax.random.bernoulli(k3, self.p)
        w_new = jnp.where(refresh, state.y, state.w)

        return AdianaState(x, y_new, z_new, w_new, h_new, key)

    def bits_per_round(self, d: int) -> int:
        from ..wire.report import wire_cost

        # two compressed vectors per round
        return 2 * wire_cost(self.comp, (d,), encoded=False).analytic_bits

    def run(self, x0, n, num_rounds, seed: int = 0):
        state = self.init(x0, n, seed=seed)

        def body(state, _):
            new = self.step(state)
            return new, new.y

        final, ys = jax.lax.scan(body, state, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], ys], axis=0)


# ---------------------------------------------------------------------------
# DINGO
# ---------------------------------------------------------------------------


class Dingo:
    """DINGO (Crane & Roosta 2019) with the paper's constants
    theta = 1e-4, phi = 1e-6, rho = 1e-4 and backtracking from
    {1, 2^-1, ..., 2^-10} on the gradient-norm objective.

    Case 1: p = -mean_i H_i^+ g       if <p_avg, H g> >= theta ||g||^2
    Case 2: per-i keep p_i = -H_i^+ g where local condition holds
    Case 3: lagrangian correction via the phi-regularized system.
    H_i^+ is implemented as a solve with the (SPD, lam-regularized)
    local Hessian — exact for our strongly convex losses.
    """

    def __init__(self, value_fn, grad_fn, hess_fn, theta=1e-4, phi=1e-6,
                 rho=1e-4):
        self.value_fn = value_fn
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.theta = theta
        self.phi = phi
        self.rho = rho

    def direction(self, x):
        grads = self.grad_fn(x)               # (n, d)
        hesses = self.hess_fn(x)              # (n, d, d)
        g = jnp.mean(grads, axis=0)
        d = x.shape[0]
        eye = jnp.eye(d, dtype=x.dtype)

        hg = jnp.mean(hesses, axis=0) @ g                       # \bar H g
        gnorm2 = jnp.dot(g, g)
        thresh = self.theta * gnorm2

        p_pinv = jax.vmap(lambda h: -jnp.linalg.solve(h, g))(hesses)   # (n, d)
        # phi-regularized least-squares direction: -(H^2 + phi^2 I)^{-1} H g
        p_reg = jax.vmap(
            lambda h: -jnp.linalg.solve(h @ h + self.phi**2 * eye, h @ g)
        )(hesses)

        p1 = jnp.mean(p_pinv, axis=0)
        case1 = jnp.dot(p1, hg) <= -thresh

        local_ok = jax.vmap(lambda p: jnp.dot(p, hg) <= -thresh)(p_pinv)
        # case-3 lagrangian correction per device where local_ok fails
        def correct(h, p):
            ht_hg = jnp.linalg.solve(h @ h + self.phi**2 * eye, hg)
            num = jnp.dot(p, hg) + thresh
            den = jnp.maximum(jnp.dot(ht_hg, hg), 1e-30)
            lam = jnp.maximum(num / den, 0.0)
            return p - lam * ht_hg

        p_fixed = jax.vmap(correct)(hesses, p_reg)
        p_mixed = jnp.where(local_ok[:, None], p_pinv, p_fixed)
        p23 = jnp.mean(p_mixed, axis=0)

        return jnp.where(case1, p1, p23), g

    def step(self, x):
        p, g = self.direction(x)
        # backtracking on 1/2||grad||^2 with slope rho ||p||... per DINGO:
        # accept largest a in {1, .., 2^-10} with
        #   ||grad(x + a p)||^2 <= ||g||^2 + 2 a rho <p, \bar H g>
        hg = jnp.mean(self.hess_fn(x), axis=0) @ g
        slope = 2.0 * self.rho * jnp.dot(p, hg)
        gnorm2 = jnp.dot(g, g)

        alphas = 2.0 ** -jnp.arange(11.0)

        def probe(a):
            gn = jnp.mean(self.grad_fn(x + a * p), axis=0)
            return jnp.dot(gn, gn) <= gnorm2 + a * slope

        ok = jax.vmap(probe)(alphas)
        idx = jnp.argmax(ok)  # first acceptable (largest stepsize)
        a = jnp.where(jnp.any(ok), alphas[idx], alphas[-1])
        return x + a * p

    @staticmethod
    def bits_per_round(d: int) -> int:
        """Both directions, per the paper's fair accounting: DINGO moves
        several d-vectors per iteration (g aggregation, H g, the two
        candidate directions, broadcasts of x and g)."""
        return 6 * d * FLOAT_BITS

    def run(self, x0, num_rounds):
        def body(x, _):
            xn = self.step(x)
            return xn, xn

        final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], xs], axis=0)


# ---------------------------------------------------------------------------
# NL1 (Newton Learn, GLM-only predecessor)
# ---------------------------------------------------------------------------


class NL1State(NamedTuple):
    x: jax.Array
    gamma: jax.Array  # (n, m) learned phi'' coefficients
    key: jax.Array


class NL1:
    """NL1 of Islamov et al. 2021 for eq. (2) GLMs.

    Learns gamma_ij -> phi''_ij(a_ij^T x*) with Rand-K compression on the
    per-silo coefficient vector; the server reconstructs
    H^k = (1/nm) sum_ij gamma_ij a_ij a_ij^T + lam I (which requires the
    touched data points a_ij — the privacy leak). Model update is the
    regularized Newton step. eta = 1/(1+omega) with omega = m/K - 1.
    """

    def __init__(self, data, k: int = 1):
        # data: objectives.LogRegData
        self.data = data
        self.k = k
        m = data.a.shape[1]
        self.eta = k / m  # = 1/(omega+1), omega = m/k - 1

    def init(self, x0, seed: int = 0) -> NL1State:
        from .objectives import silo_phi2

        gamma0 = jax.vmap(lambda a, b: silo_phi2(x0, a, b))(self.data.a, self.data.b)
        return NL1State(x0, gamma0, jax.random.PRNGKey(seed))

    def step(self, state: NL1State) -> NL1State:
        from .objectives import batch_grad, silo_phi2

        n, m = state.gamma.shape
        d = state.x.shape[0]
        key, sub = jax.random.split(state.key)
        keys = jax.random.split(sub, n)

        phi2 = jax.vmap(lambda a, b: silo_phi2(state.x, a, b))(self.data.a, self.data.b)
        delta = phi2 - state.gamma                          # (n, m)

        def randk_vec(v, k_):
            idx = jax.random.choice(k_, m, (self.k,), replace=False)
            mask = jnp.zeros((m,), v.dtype).at[idx].set(1.0)
            return v * mask * (m / self.k)

        comp = jax.vmap(randk_vec)(delta, keys)
        gamma_new = jnp.clip(state.gamma + self.eta * comp, 0.0, 0.25)

        # server-side Hessian from learned coefficients (+ ridge)
        def silo_h(gam, a):
            return (a.T * gam) @ a / m

        h = jnp.mean(jax.vmap(silo_h)(gamma_new, self.data.a), axis=0) \
            + self.data.lam * jnp.eye(d, dtype=state.x.dtype)
        g = jnp.mean(batch_grad(state.x, self.data), axis=0)
        x_new = state.x - jnp.linalg.solve(h, g)
        return NL1State(x_new, gamma_new, key)

    def bits_per_round(self, d: int) -> int:
        # gradient + K coefficients + K data points of dimension d
        return d * FLOAT_BITS + self.k * (FLOAT_BITS + INDEX_BITS) \
            + self.k * d * FLOAT_BITS

    def run(self, x0, num_rounds, seed: int = 0):
        state = self.init(x0, seed=seed)

        def body(state, _):
            new = self.step(state)
            return new, new.x

        final, xs = jax.lax.scan(body, state, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], xs], axis=0)


# ---------------------------------------------------------------------------
# DORE (bidirectional residual compression)
# ---------------------------------------------------------------------------


class DoreState(NamedTuple):
    x_hat: jax.Array    # (d,) model replica tracked by everyone
    x: jax.Array        # (d,) server model
    h_i: jax.Array      # (n, d) gradient shifts
    key: jax.Array


class Dore:
    """DORE [Liu et al. 2020]: DIANA-style uplink (gradient residual
    compression with shifts) + compressed downlink model residual tracked
    by replicas. Theoretical-flavored stepsizes as in DIANA; downlink
    learning rate eta_m = 1/(1+omega_m)."""

    def __init__(self, grad_fn, comp_up: Compressor, comp_down: Compressor,
                 smooth_l: float, n: int, omega_up: float, omega_down: float):
        self.grad_fn = grad_fn
        self.comp_up = comp_up
        self.comp_down = comp_down
        self.alpha = 1.0 / (1.0 + omega_up)
        self.gamma = 1.0 / (smooth_l * (1.0 + 6.0 * omega_up / n))
        self.eta_m = 1.0 / (1.0 + omega_down)

    def init(self, x0, n, seed: int = 0) -> DoreState:
        d = x0.shape[0]
        return DoreState(x0, x0, jnp.zeros((n, d), x0.dtype), jax.random.PRNGKey(seed))

    def step(self, state: DoreState) -> DoreState:
        n = state.h_i.shape[0]
        key, k_up, k_down = jax.random.split(state.key, 3)
        keys = jax.random.split(k_up, n)

        grads = self.grad_fn(state.x_hat)              # gradients at the replica
        delta = jax.vmap(self.comp_up)(grads - state.h_i, keys)
        g_hat = jnp.mean(state.h_i + delta, axis=0)
        h_new = state.h_i + self.alpha * delta

        x_new = state.x - self.gamma * g_hat
        q = self.comp_down(x_new - state.x_hat, k_down)
        x_hat_new = state.x_hat + self.eta_m * q

        return DoreState(x_hat_new, x_new, h_new, key)

    def bits_per_round(self, d: int) -> tuple[int, int]:
        from ..wire.report import wire_cost

        return (wire_cost(self.comp_up, (d,), encoded=False).analytic_bits,
                wire_cost(self.comp_down, (d,), encoded=False).analytic_bits)

    def run(self, x0, n, num_rounds, seed: int = 0):
        state = self.init(x0, n, seed=seed)

        def body(state, _):
            new = self.step(state)
            return new, new.x

        final, xs = jax.lax.scan(body, state, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], xs], axis=0)


# ---------------------------------------------------------------------------
# Artemis (bidirectional compression + partial participation)
# ---------------------------------------------------------------------------


class ArtemisState(NamedTuple):
    x: jax.Array
    h_i: jax.Array
    key: jax.Array


class Artemis:
    """Artemis [Philippenko & Dieuleveut 2021] in the variant the paper
    benchmarks: uplink random-sparsification of gradient differences with
    memory, uncompressed downlink descent direction, tau active nodes."""

    def __init__(self, grad_fn, comp_up: Compressor, smooth_l: float, n: int,
                 omega: float, tau: int):
        self.grad_fn = grad_fn
        self.comp = comp_up
        self.tau = tau
        self.n = n
        self.alpha = 1.0 / (1.0 + omega)
        self.gamma = 1.0 / (smooth_l * (1.0 + 6.0 * omega * n / (tau * n)))

    def init(self, x0, n, seed: int = 0) -> ArtemisState:
        d = x0.shape[0]
        return ArtemisState(x0, jnp.zeros((n, d), x0.dtype), jax.random.PRNGKey(seed))

    def step(self, state: ArtemisState) -> ArtemisState:
        n = state.h_i.shape[0]
        key, k_sel, k_up = jax.random.split(state.key, 3)
        perm = jax.random.permutation(k_sel, n)
        active = jnp.zeros((n,), bool).at[perm[: self.tau]].set(True)

        keys = jax.random.split(k_up, n)
        grads = self.grad_fn(state.x)
        delta = jax.vmap(self.comp)(grads - state.h_i, keys)
        delta = jnp.where(active[:, None], delta, 0.0)

        g_hat = jnp.mean(state.h_i, axis=0) + jnp.sum(delta, axis=0) / self.tau
        h_new = state.h_i + self.alpha * delta

        return ArtemisState(state.x - self.gamma * g_hat, h_new, key)

    def bits_per_round(self, d: int) -> int:
        from ..wire.report import wire_cost

        # per active device
        return wire_cost(self.comp, (d,), encoded=False).analytic_bits

    def run(self, x0, n, num_rounds, seed: int = 0):
        state = self.init(x0, n, seed=seed)

        def body(state, _):
            new = self.step(state)
            return new, new.x

        final, xs = jax.lax.scan(body, state, None, length=num_rounds)
        return final, jnp.concatenate([x0[None], xs], axis=0)
