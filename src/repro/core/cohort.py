"""Cross-device cohort layer on top of FedNL-PP.

FedNL-PP (Algorithm 2) already handles partial participation: tau-of-n
uniform sampling with zero-weighted inactive silos. The paper runs it
at cross-silo scale (n ≈ 20). A cross-device deployment changes three
things, all captured here in ONE spec:

  * the registered *population* N is large (thousands), and every round
    samples a *cohort* of K participants from it;
  * participants arrive asynchronously — the traffic model's per-silo
    upload times (``repro.wire.traffic``, fl-cross-device preset by
    default) decide who makes the round's deadline, set at a quantile
    of the cohort's arrival distribution;
  * stragglers are not dropped: their contributions land with a
    staleness-decayed weight (1 + s)^(-beta) — the async-FL
    staleness discount — through the ``weights=`` argument of
    ``Compressor.aggregate``, the same payload-space weighting the 0/1
    participation mask uses.

``CohortSpec`` is the single configuration object: ``ExperimentSpec``
cells, the ``Sweep`` runner, and the ``server_aggregate`` bench axis
all consume it unchanged instead of growing per-callsite
n_silos/participation kwargs.

Determinism: cohort sampling is ``jax.random`` keyed off the round key
(same seed -> same cohorts); arrival times are host numpy keyed off
``CohortSpec.seed`` and STATIC shapes only — they become jaxpr
constants, so the step stays one jitted program and never reads a
traced value on the host.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..engine.method import Oracles, register
from .compressors import Compressor
from .fednl_pp import FedNLPP
from .linalg import frob_norm, solve_newton_system


@dataclasses.dataclass(frozen=True)
class CohortSpec:
    """Cross-device participation model — one object, consumed uniformly
    by ``ExperimentSpec``, ``Sweep``, and the bench axis.

    population:        registered clients N; None adopts the problem's
                       silo count at init (and a set value must match it
                       — the oracles are built per-silo)
    cohort:            participants K sampled uniformly per round
    staleness_beta:    straggler discount exponent — a contribution s
                       rounds stale is weighted (1 + s)^(-beta); 0
                       keeps FedNL-PP's pure 0/1 mask
    link:              traffic-model preset (or LinkModel) whose
                       per-silo upload-time draws decide who makes the
                       deadline ("fl-cross-device" by default)
    deadline_quantile: the round closes at this quantile of the
                       cohort's arrival-time distribution (1.0 = wait
                       for every straggler — fully synchronous)
    seed:              seeds the HOST-side arrival draws (numpy); the
                       cohort sampling itself rides the method's jax
                       key chain
    """

    cohort: int
    population: Optional[int] = None
    staleness_beta: float = 0.5
    link: object = "fl-cross-device"
    deadline_quantile: float = 0.8
    seed: int = 0

    def __post_init__(self):
        if self.cohort < 1:
            raise ValueError(f"cohort must be >= 1, got {self.cohort}")
        if self.population is not None and self.population < self.cohort:
            raise ValueError(
                f"population ({self.population}) smaller than cohort "
                f"({self.cohort})")
        if not 0.0 < self.deadline_quantile <= 1.0:
            raise ValueError("deadline_quantile must be in (0, 1], got "
                             f"{self.deadline_quantile}")
        if self.staleness_beta < 0.0:
            raise ValueError("staleness_beta must be >= 0, got "
                             f"{self.staleness_beta}")


def sample_cohort(key: jax.Array, population: int,
                  cohort: int) -> jax.Array:
    """(population,) bool mask of a uniform K-of-N cohort — exactly
    ``min(cohort, population)`` True entries, deterministic per key."""
    perm = jax.random.permutation(key, population)
    k = min(int(cohort), int(population))
    return jnp.zeros((population,), bool).at[perm[:k]].set(True)


def arrival_times(spec: CohortSpec, n: int,
                  bits_per_silo: float) -> np.ndarray:
    """(n,) HOST-side per-silo upload seconds for one round, drawn from
    the spec's link model — deterministic in ``spec.seed`` and static
    shapes only (safe to call at trace time; the result is a jaxpr
    constant)."""
    from ..wire.traffic import link_model

    link = link_model(spec.link)
    return link.silo_seconds(float(bits_per_silo), int(n), seed=spec.seed)


def on_time_mask(times: np.ndarray, deadline_quantile: float) -> np.ndarray:
    """(n,) bool: who beats the round deadline, set at the configured
    quantile of the cohort's arrival distribution."""
    deadline = np.quantile(times, float(deadline_quantile))
    return times <= deadline


def staleness_weights(staleness: jax.Array, beta: float) -> jax.Array:
    """(1 + s)^(-beta) straggler discount; beta = 0 gives weight 1."""
    s = jnp.maximum(staleness, 0).astype(jnp.result_type(float))
    return (1.0 + s) ** (-float(beta))


class CohortFedNLPPState(NamedTuple):
    w: jax.Array           # (n, d) stale local models
    h_local: jax.Array     # (n, d, d)
    l_local: jax.Array     # (n,)
    g_local: jax.Array     # (n, d)
    h_global: jax.Array    # (d, d)
    l_global: jax.Array    # ()
    g_global: jax.Array    # (d,)
    x: jax.Array           # (d,)
    key: jax.Array
    step: jax.Array
    last_round: jax.Array  # (n,) int32 — round each silo last landed


class CohortFedNLPP(FedNLPP):
    """FedNL-PP with the cohort layer: K-of-N sampling, deadline-based
    arrival, staleness-weighted straggler contributions.

    Server update: H^{k+1} = H^k + alpha * mean_i w_i S_i with
    w_i = active_i * (1 if on time else (1 + staleness_i)^(-beta)); the
    local H_i applies the SAME weighted increment, so the server
    aggregate stays the exact mean of the local updates (the line 18-20
    consistency FedNL-PP relies on). beta = 0 and deadline_quantile = 1
    recover FedNL-PP with tau = cohort exactly."""

    silo_fields = FedNLPP.silo_fields + ("last_round",)

    def __init__(
        self,
        grad_fn_at: Callable[[jax.Array], jax.Array],
        hess_fn_at: Callable[[jax.Array], jax.Array],
        compressor: Compressor,
        cohort: CohortSpec,
        alpha: float = 1.0,
    ):
        super().__init__(grad_fn_at, hess_fn_at, compressor,
                         tau=cohort.cohort, alpha=alpha)
        self.cohort = cohort

    def init(self, x0: jax.Array, n: int, seed: int = 0):
        if (self.cohort.population is not None
                and int(self.cohort.population) != int(n)):
            raise ValueError(
                f"CohortSpec.population={self.cohort.population} but the "
                f"problem has n={n} silos")
        base = super().init(x0, n, seed=seed)
        return CohortFedNLPPState(
            *base, last_round=jnp.zeros((n,), jnp.int32))

    def _round_weights(self, state: CohortFedNLPPState,
                       active: jax.Array) -> jax.Array:
        """(n,) per-silo aggregation weights for this round: 0 for the
        unsampled, 1 for on-time arrivals, the staleness discount for
        stragglers. Arrival times are trace-time host constants (static
        shapes + CohortSpec.seed only)."""
        from ..wire.report import wire_cost

        n = state.w.shape[0]
        d = state.x.shape[0]
        bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        times = arrival_times(self.cohort, n, bits)
        on_time = jnp.asarray(on_time_mask(
            times, self.cohort.deadline_quantile))
        staleness = state.step - state.last_round
        decay = staleness_weights(staleness, self.cohort.staleness_beta)
        late_w = decay.astype(state.x.dtype)
        w = jnp.where(on_time, jnp.ones_like(late_w), late_w)
        return jnp.where(active, w, jnp.zeros_like(w))

    def step(self, state: CohortFedNLPPState) -> CohortFedNLPPState:
        n, d = state.w.shape
        key, k_sel, k_comp = jax.random.split(state.key, 3)

        h_eff = (state.h_global
                 + state.l_global * jnp.eye(d, dtype=state.x.dtype))
        x_new = solve_newton_system(h_eff, state.g_global)

        active = sample_cohort(k_sel, n, self.tau)
        wts = self._round_weights(state, active)

        silo_keys = jax.random.split(k_comp, n)
        hess_new = self.hess_fn(x_new)
        grads_new = self.grad_fn(x_new)

        payloads, _ = self._uplink_diff_payloads(hess_new, state.h_local,
                                                 silo_keys)
        s_i = self._local_hessians(payloads, (d, d))
        # the weighted increment, applied identically on device and (as
        # the payload-space weighted mean) on the server
        h_upd = state.h_local + self.alpha * wts[:, None, None] * s_i
        l_upd = jax.vmap(frob_norm)(h_upd - hess_new)
        eye = jnp.eye(d, dtype=state.x.dtype)
        g_upd = jax.vmap(lambda h, l, gi: (h + l * eye) @ x_new - gi)(
            h_upd, l_upd, grads_new)

        mask = active[:, None]
        maskm = active[:, None, None]
        w_next = jnp.where(mask, x_new[None], state.w)
        h_next = jnp.where(maskm, h_upd, state.h_local)
        l_next = jnp.where(active, l_upd, state.l_local)
        g_next = jnp.where(mask, g_upd, state.g_local)
        last_next = jnp.where(active, state.step + 1, state.last_round)

        h_global = state.h_global + self.alpha * self._server_aggregate(
            payloads, (d, d), weights=wts)
        l_global = state.l_global + jnp.mean(
            jnp.where(active, l_upd - state.l_local, 0.0))
        g_global = state.g_global + jnp.mean(
            jnp.where(mask, g_upd - state.g_local, 0.0), axis=0)

        return CohortFedNLPPState(
            w_next, h_next, l_next, g_next, h_global, l_global, g_global,
            x_new, key, state.step + 1, last_next)


@register("fednl-cohort")
def _make_fednl_cohort(oracles: Oracles, compressor, **params):
    return CohortFedNLPP(oracles.grad, oracles.hess, compressor, **params)
