"""Compression operators for FedNL (Definitions 3.2 and 3.3).

Two families, exactly as in the paper:

* ``ContractiveCompressor``  (class C(delta), Def 3.3, deterministic):
    ||C(M)||_F <= ||M||_F   and   ||C(M) - M||_F^2 <= (1 - delta) ||M||_F^2
  Examples: Top-K (delta = K/d^2), Rank-R (delta = R/d), PowerSGD-R
  (scaled so the first inequality holds), block-local Top-K.

* ``UnbiasedCompressor``  (class B(omega), Def 3.2, randomized):
    E[C(M)] = M   and   E||C(M) - M||_F^2 <= omega ||M||_F^2
  Examples: Rand-K (omega = d^2/K - 1), random dithering (vectors).

Every compressor reports ``bits(shape)`` — the uplink payload in bits for
one application — which powers the paper's communicated-bits x-axis.
Matrix compressors operate on (d, d) arrays; vector compressors on (d,).

All operators are pure JAX and jittable. Randomized ones take an explicit
``key``.
"""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp

FLOAT_BITS = 64  # the paper counts double-precision floats
INDEX_BITS = 32


# ---------------------------------------------------------------------------
# Base classes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A compression operator with analytic byte accounting."""

    def __call__(self, m: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
        raise NotImplementedError

    def bits(self, shape: tuple[int, ...]) -> int:
        raise NotImplementedError

    # Class parameters (exactly one of these is not None).
    @property
    def delta(self) -> Optional[float]:  # contractive parameter
        return None

    @property
    def omega(self) -> Optional[float]:  # unbiased variance parameter
        return None

    @property
    def deterministic(self) -> bool:
        return self.delta is not None


# ---------------------------------------------------------------------------
# Contractive compressors  C(delta)  — Def 3.3
# ---------------------------------------------------------------------------


def _topk_dense(m: jax.Array, k: int) -> jax.Array:
    """Keep the k largest-magnitude entries of ``m`` (any shape), zero rest."""
    flat = m.reshape(-1)
    k = min(k, flat.shape[0])
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(m.shape)


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Global Top-K over all entries (paper A.3.3). delta = K / numel.

    ``symmetric=True`` applies the operator to the lower triangle only and
    mirrors it (the paper's symmetry-preserving variant); K then counts
    kept lower-triangular entries.
    """

    k: int
    symmetric: bool = False

    def __call__(self, m: jax.Array, key=None) -> jax.Array:
        if self.symmetric and m.ndim == 2 and m.shape[0] == m.shape[1]:
            d = m.shape[0]
            tril = jnp.tril(m)
            c = _topk_dense(tril, self.k)
            return c + c.T - jnp.diag(jnp.diag(c))
        return _topk_dense(m, self.k)

    def bits(self, shape) -> int:
        # value + (row, col) index per kept entry
        return self.k * (FLOAT_BITS + INDEX_BITS)

    @property
    def delta(self) -> float:
        return None  # depends on shape; use delta_for

    def delta_for(self, shape) -> float:
        numel = 1
        for s in shape:
            numel *= s
        if self.symmetric and len(shape) == 2:
            numel = shape[0] * (shape[0] + 1) // 2
        return min(1.0, self.k / numel)

    @property
    def deterministic(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class BlockTopK(Compressor):
    """TPU-native block-local Top-K: keep the top ``k_per_block`` entries of
    every (b x b) tile. Contractive with delta = k_per_block / b^2 (the
    contraction inequality holds per tile and the Frobenius norm is
    separable over tiles). This is the operator the Pallas kernel
    implements; this version is the pure-jnp reference semantics.
    """

    k_per_block: int
    block: int = 128

    def __call__(self, m: jax.Array, key=None) -> jax.Array:
        d0, d1 = m.shape
        b = self.block
        p0, p1 = (-d0) % b, (-d1) % b
        mp = jnp.pad(m, ((0, p0), (0, p1)))
        n0, n1 = mp.shape[0] // b, mp.shape[1] // b
        tiles = mp.reshape(n0, b, n1, b).transpose(0, 2, 1, 3).reshape(n0 * n1, b * b)
        k = min(self.k_per_block, b * b)
        _, idx = jax.lax.top_k(jnp.abs(tiles), k)
        vals = jnp.take_along_axis(tiles, idx, axis=1)
        out = jnp.zeros_like(tiles)
        out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)
        out = out.reshape(n0, n1, b, b).transpose(0, 2, 1, 3).reshape(mp.shape)
        return out[:d0, :d1]

    def bits(self, shape) -> int:
        b = self.block
        nblk = -(-shape[0] // b) * -(-shape[1] // b)
        return nblk * self.k_per_block * (FLOAT_BITS + INDEX_BITS)

    @property
    def delta(self) -> float:
        return self.k_per_block / (self.block * self.block)

    @property
    def deterministic(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class BlockTopKThreshold(Compressor):
    """Block-local Top-K via threshold bisection — the pure-jnp mirror of
    the Pallas kernel (kernels/block_topk). Selection by ~32 rounds of
    compare+count instead of a sort: O(iters * n) vector ops vs
    O(n log n) scalar-ish sort work, which matters when the compressor
    runs inside every optimizer step (second_order/fednl_precond).
    Keeps count in [k, k + #ties] per tile; same contractive class,
    delta = k_per_block / block^2."""

    k_per_block: int
    block: int = 128
    iters: int = 32

    def __call__(self, m: jax.Array, key=None) -> jax.Array:
        d0, d1 = m.shape
        b = self.block
        p0, p1 = (-d0) % b, (-d1) % b
        mp = jnp.pad(m, ((0, p0), (0, p1)))
        n0, n1 = mp.shape[0] // b, mp.shape[1] // b
        tiles = mp.reshape(n0, b, n1, b).transpose(0, 2, 1, 3) \
            .reshape(n0 * n1, b * b)
        ax = jnp.abs(tiles).astype(jnp.float32)
        k = min(self.k_per_block, b * b)

        hi = jnp.max(ax, axis=1)
        lo = jnp.zeros_like(hi)

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            cnt = jnp.sum(ax >= mid[:, None], axis=1)
            too_many = cnt > k
            return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

        lo, hi = jax.lax.fori_loop(0, self.iters, body, (lo, hi))
        out = jnp.where(ax >= hi[:, None], tiles, jnp.zeros_like(tiles))
        out = out.reshape(n0, n1, b, b).transpose(0, 2, 1, 3).reshape(mp.shape)
        return out[:d0, :d1]

    def bits(self, shape) -> int:
        b = self.block
        nblk = -(-shape[0] // b) * -(-shape[1] // b)
        return nblk * self.k_per_block * (FLOAT_BITS + INDEX_BITS)

    @property
    def delta(self) -> float:
        return self.k_per_block / (self.block * self.block)

    @property
    def deterministic(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class RankR(Compressor):
    """Exact Rank-R truncation (paper A.3.2). delta = R/d. Deterministic.

    ``symmetric=True`` (default — every matrix FedNL compresses is a
    Hessian difference): the rank-R approximation of M = Q diag(lam) Q^T
    keeps the R largest-|lam| eigenpairs, computed with eigh. This is
    exactly A.3.2's symmetric case (output sum sigma_i u_i u_i^T) and is
    numerically robust where batched divide-and-conquer SVD (gesdd) can
    emit NaNs inside fused XLA:CPU programs. ``symmetric=False`` uses the
    general SVD.
    """

    r: int
    symmetric: bool = True

    def __call__(self, m: jax.Array, key=None) -> jax.Array:
        if self.symmetric:
            sym = 0.5 * (m + m.T)
            lam, q = jnp.linalg.eigh(sym)
            r = min(self.r, lam.shape[0])
            _, idx = jax.lax.top_k(jnp.abs(lam), r)
            lam_r = lam[idx]
            q_r = q[:, idx]
            return (q_r * lam_r) @ q_r.T
        u, s, vt = jnp.linalg.svd(m, full_matrices=False)
        r = min(self.r, s.shape[0])
        return (u[:, :r] * s[:r]) @ vt[:r, :]

    def bits(self, shape) -> int:
        # R singular triples: sigma + u (d) + v (d)
        return self.r * FLOAT_BITS * (1 + shape[0] + shape[1])

    def delta_for(self, shape) -> float:
        return min(1.0, self.r / min(shape))

    @property
    def delta(self) -> float:
        return None  # shape dependent; use delta_for

    @property
    def deterministic(self) -> bool:
        return True


def _orthonormalize(q: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR; matmul-heavy, TPU friendly."""
    qq, _ = jnp.linalg.qr(q)
    return qq


@dataclasses.dataclass(frozen=True)
class PowerSGD(Compressor):
    """PowerSGD-style rank-R approximation via ``iters`` rounds of subspace
    iteration (Vogels et al. 2019; benchmarked by the paper in Fig. 3/5).

    Scaled per Definition 3.3's remark so ||C(M)||_F <= ||M||_F always
    holds; with enough iterations this approaches RankR. Deterministic
    given the fixed seed for the starting subspace.
    """

    r: int
    iters: int = 2
    seed: int = 0

    def __call__(self, m: jax.Array, key=None) -> jax.Array:
        d1 = m.shape[1]
        q = jax.random.normal(jax.random.PRNGKey(self.seed), (d1, self.r), m.dtype)
        q = _orthonormalize(q)
        for _ in range(self.iters):
            p = _orthonormalize(m @ q)          # (d0, r)
            q = _orthonormalize(m.T @ p)        # (d1, r)
        p = m @ q                                # un-normalized left factor
        approx = p @ q.T
        # contraction-preserving rescale (Def 3.3 remark)
        num = jnp.linalg.norm(m)
        den = jnp.linalg.norm(approx)
        scale = jnp.minimum(1.0, num / jnp.maximum(den, 1e-30))
        return approx * scale

    def bits(self, shape) -> int:
        return self.r * FLOAT_BITS * (shape[0] + shape[1])

    def delta_for(self, shape) -> float:
        # conservative: one power iteration already dominates Rank-R energy
        # capture of a random subspace; we report the Rank-R bound.
        return min(1.0, self.r / min(shape))

    @property
    def deterministic(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """C = I (classical Newton's communication)."""

    def __call__(self, m, key=None):
        return m

    def bits(self, shape) -> int:
        numel = 1
        for s in shape:
            numel *= s
        return numel * FLOAT_BITS

    @property
    def delta(self) -> float:
        return 1.0

    @property
    def deterministic(self) -> bool:
        return True


@dataclasses.dataclass(frozen=True)
class Zero(Compressor):
    """C = 0 (Newton-Zero / Newton-Star corner of the Newton triangle)."""

    def __call__(self, m, key=None):
        return jnp.zeros_like(m)

    def bits(self, shape) -> int:
        return 0

    @property
    def delta(self) -> float:
        return 0.0

    @property
    def deterministic(self) -> bool:
        return True


# ---------------------------------------------------------------------------
# Unbiased compressors  B(omega)  — Def 3.2
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Rand-K with d^2/K rescale (paper A.3.4). omega = numel/K - 1."""

    k: int
    symmetric: bool = False

    def __call__(self, m: jax.Array, key: jax.Array = None) -> jax.Array:
        assert key is not None, "RandK is randomized; pass a PRNG key"
        flat = m.reshape(-1)
        n = flat.shape[0]
        k = min(self.k, n)
        idx = jax.random.choice(key, n, (k,), replace=False)
        mask = jnp.zeros((n,), m.dtype).at[idx].set(1.0)
        out = flat * mask * (n / k)
        return out.reshape(m.shape)

    def bits(self, shape) -> int:
        return self.k * (FLOAT_BITS + INDEX_BITS)

    def omega_for(self, shape) -> float:
        numel = 1
        for s in shape:
            numel *= s
        return numel / self.k - 1.0

    @property
    def omega(self) -> float:
        return None  # shape dependent

    @property
    def deterministic(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class RandomDithering(Compressor):
    """Random dithering with s levels, q-norm (paper A.3.1; used for
    DIANA/ADIANA on vectors). omega <= min(d/s^2, sqrt(d)/s) for q=2.
    """

    s: int
    q: float = 2.0

    def __call__(self, x: jax.Array, key: jax.Array = None) -> jax.Array:
        assert key is not None
        norm = jnp.linalg.norm(x.reshape(-1), ord=self.q)
        norm = jnp.maximum(norm, 1e-30)
        y = jnp.abs(x) / norm * self.s          # in [0, s]
        low = jnp.floor(y)
        prob = y - low
        bump = jax.random.bernoulli(key, prob, x.shape).astype(x.dtype)
        levels = (low + bump) / self.s
        out = jnp.sign(x) * norm * levels
        return jnp.where(norm > 1e-29, out, jnp.zeros_like(x))

    def bits(self, shape) -> int:
        numel = 1
        for s_ in shape:
            numel *= s_
        import math

        level_bits = max(1, math.ceil(math.log2(self.s + 1)))
        return FLOAT_BITS + numel * (1 + level_bits)  # norm + sign+level per entry

    def omega_for(self, shape) -> float:
        import math

        numel = 1
        for s_ in shape:
            numel *= s_
        return min(numel / self.s**2, math.sqrt(numel) / self.s)

    @property
    def deterministic(self) -> bool:
        return False


@dataclasses.dataclass(frozen=True)
class NaturalSparsification(Compressor):
    """Bernoulli(p) sparsification with 1/p rescale — unbiased,
    omega = 1/p - 1. Used by FedNL-BC's uplink gradient scheme analysis
    and as a generic cheap unbiased operator."""

    p: float

    def __call__(self, x: jax.Array, key: jax.Array = None) -> jax.Array:
        assert key is not None
        mask = jax.random.bernoulli(key, self.p, x.shape).astype(x.dtype)
        return x * mask / self.p

    def bits(self, shape) -> int:
        numel = 1
        for s in shape:
            numel *= s
        return int(self.p * numel) * (FLOAT_BITS + INDEX_BITS)

    @property
    def omega(self) -> float:
        return 1.0 / self.p - 1.0

    @property
    def deterministic(self) -> bool:
        return False


# ---------------------------------------------------------------------------
# Stepsize rules (Assumptions 3.4 / 3.5 and the constants (A, B) of eq. (5))
# ---------------------------------------------------------------------------


def alpha_for(comp: Compressor, shape, rule: str = "auto") -> float:
    """Theoretical Hessian learning rate for a compressor.

    rule = 'one'        -> alpha = 1               (Assumption 3.4(ii))
    rule = 'contract'   -> alpha = 1 - sqrt(1-delta)  (Assumption 3.4(i))
    rule = 'unbiased'   -> alpha = 1/(omega+1)     (Assumption 3.5)
    rule = 'auto'       -> 'one' for contractive, 'unbiased' otherwise
    """
    delta = comp.delta
    if delta is None and hasattr(comp, "delta_for"):
        delta = comp.delta_for(shape)
    omega = comp.omega
    if omega is None and hasattr(comp, "omega_for"):
        omega = comp.omega_for(shape)

    if rule == "auto":
        rule = "one" if comp.deterministic else "unbiased"
    if rule == "one":
        return 1.0
    if rule == "contract":
        assert delta is not None
        return 1.0 - (1.0 - delta) ** 0.5
    if rule == "unbiased":
        assert omega is not None
        return 1.0 / (omega + 1.0)
    raise ValueError(rule)


def ab_constants(comp: Compressor, shape, alpha: float) -> tuple[float, float]:
    """(A, B) of eq. (5), selecting the assumption matching (comp, alpha)."""
    delta = comp.delta
    if delta is None and hasattr(comp, "delta_for"):
        delta = comp.delta_for(shape)
    if comp.deterministic:
        if alpha == 1.0:
            return delta / 4.0, 6.0 / delta - 3.5
        return alpha**2, alpha
    return alpha, alpha
