"""Compression operators for FedNL (Definitions 3.2 and 3.3) — the
wire-format API.

Two operator families, exactly as in the paper:

* ``C(delta)``  (Def 3.3, deterministic, contractive):
    ||C(M)||_F <= ||M||_F   and   ||C(M) - M||_F^2 <= (1 - delta) ||M||_F^2
  Examples: Top-K (delta = K/d^2), Rank-R (delta = R/d), PowerSGD-R
  (scaled so the first inequality holds), block-local Top-K.

* ``B(omega)``  (Def 3.2, randomized, unbiased):
    E[C(M)] = M   and   E||C(M) - M||_F^2 <= omega ||M||_F^2
  Examples: Rand-K (omega = d^2/K - 1), random dithering (vectors).

Every compressor is a *wire codec*:

    payload = comp.compress(m, key)        # fixed-shape jittable pytree
    dense   = comp.decompress(payload, m.shape)
    comp(m, key) == decompress(compress(m, key), m.shape)   # bit-identical

The payload is the first-class object a device actually uplinks —
indices+values for the sparsifiers, factors for the low-rank family,
levels+norm for dithering — and ``payload.bits()`` is the *measured*
wire size, derived from the payload's own arrays (dtype widths x
static shapes), not asserted. The sparsifier payloads additionally
quote an entropy-coded index stream, ``bits(index_coding="entropy")``:
the log2 C(universe, k) information cost of the index set (the
paper-style k*log2(d^2/k) accounting) instead of k raw 32-bit ints.
``comp.spec(shape)`` returns the analytic
``CompSpec(delta, omega, bits, deterministic)`` consumed by
``alpha_for`` / ``ab_constants``; ``payload_bits`` measures the payload
via ``jax.eval_shape`` (no compute, so it is exact for any shape).

The server never needs the per-silo dense matrices: ``comp.aggregate``
consumes the *stacked* payloads of all n silos (leading silo axis, as
produced by ``jax.vmap(comp.compress)``) and returns the dense mean
``S = mean_i S_i`` directly from payload space — scatter-add into one
(d, d) accumulator for the sparsifiers (Pallas kernel on TPU:
``kernels/scatter_accum``, which tiles the accumulator once the padded
matrix outgrows its VMEM budget, so the fast path holds at any d), one
stacked-factor matmul for the low-rank family, a direct mean for
dense/dithered wires. The generic fallback is
decompress-then-mean; ``scale_payload`` reweights per-silo
contributions (zero weight = silo absent), which is how partial
participation masks the aggregate.

Compressors self-register in the string-keyed registry (mirroring the
Method registry): ``make_compressor("rankr", 1) -> RankR(1)``.

All operators are pure JAX; payloads are registered pytrees, so
``compress``/``decompress`` vmap over a silo axis with static payload
shapes. Randomized operators take an explicit ``key``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

FLOAT_BITS = 64  # the paper counts double-precision floats
INDEX_BITS = 32


def numel(shape) -> int:
    """Product of a shape tuple (the paper's d^2 for matrices)."""
    out = 1
    for s in shape:
        out *= int(s)
    return out


def _dtype_bits(x) -> int:
    """Wire width of one element of ``x`` (array or ShapeDtypeStruct)."""
    return 8 * jnp.dtype(x.dtype).itemsize


def canonical_float_bits() -> int:
    """Bits of the ambient float dtype (64 under jax_enable_x64 — the
    paper's accounting — else 32). Used for the measured width of the
    uncompressed floats every method ships (gradients, l_i)."""
    return 8 * jnp.dtype(jnp.result_type(float)).itemsize


# ---------------------------------------------------------------------------
# Payloads — the wire objects
# ---------------------------------------------------------------------------
#
# Each payload is a frozen dataclass registered as a pytree: array fields
# are leaves (so payloads flow through jit/vmap/scan), everything else is
# static aux data captured at compress time. ``bits()`` reads only static
# shape/dtype structure — it works on concrete arrays and on the
# ShapeDtypeStructs ``jax.eval_shape`` produces, and it reads *trailing*
# dims so a payload vmapped over a silo axis still reports per-silo bits.


class Payload:
    """Shared wire-object surface: the one place the ``bits`` signature
    (and its ``index_coding`` semantics) is defined.

    ``index_coding="raw"`` counts index streams at INDEX_BITS per entry;
    ``"entropy"`` swaps them for the ``ceil(log2 C(universe, k))``
    information-cost estimate. Only the families that *carry* an index
    stream are affected — Sparse, BlockSparse, and indexed Dense
    payloads, which implement ``_entropy_bits``. LowRank, Dithered, and
    unindexed Dense payloads have no index stream, so for them the
    argument is a documented no-op (``_entropy_bits`` returns None and
    the raw count is the only count), not a silently-ignored kwarg
    copy-pasted per class.

    Prefer ``repro.wire.wire_cost(comp, shape)`` for cost queries — it
    returns every accounting (analytic / raw / entropy / actual encoded
    bytes) in one ``WireReport``; ``bits()`` remains as the per-payload
    primitive underneath it.
    """

    def bits(self, index_coding: str = "raw") -> int:
        """Wire size in bits of ONE payload (trailing dims — a stacked
        payload reports per-silo bits). See the class docstring for
        ``index_coding``."""
        if index_coding not in ("raw", "entropy"):
            raise ValueError(
                f"index_coding must be 'raw' or 'entropy', "
                f"got {index_coding!r}")
        if index_coding == "entropy":
            eb = self._entropy_bits()
            if eb is not None:
                return eb
        return self._raw_bits()

    def _raw_bits(self) -> int:
        raise NotImplementedError

    def _entropy_bits(self) -> Optional[int]:
        """Entropy-coded size, or None for families without an index
        stream (the ``index_coding`` no-ops)."""
        return None

    def encode(self, value_format: str = "raw") -> bytes:
        """Serialize this payload to actual wire bytes via the bitstream
        codec (``repro.wire.codec.encode``)."""
        from ..wire.codec import encode as _encode

        return _encode(self, value_format=value_format)


def _entropy_index_bits(k: int, universe: int) -> int:
    """Information cost of an (unordered) k-subset of ``universe`` slots:
    ceil(log2 C(universe, k)) — the k*log2(d^2/k)-style accounting an
    entropy-coded index stream would approach. Capped at the raw
    k*INDEX_BITS (a real codec falls back to raw when entropy coding
    would lose). Estimate only — no actual codec is implemented."""
    if k <= 0 or universe <= 0 or k >= universe:
        return 0
    ln2 = math.log(2.0)
    log2c = (math.lgamma(universe + 1) - math.lgamma(k + 1)
             - math.lgamma(universe - k + 1)) / ln2
    return min(k * INDEX_BITS, math.ceil(log2c))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class SparsePayload(Payload):
    """k (value, flat-index) pairs. Indices may be -1 (padding slots,
    dropped on decompress). ``universe`` is the number of addressable
    slots the indices were drawn from (d^2, or the triangle count for
    symmetric operators) — static metadata captured at compress time,
    consumed by the entropy-coded bits estimate and the codec header."""

    values: jax.Array   # (..., k)
    indices: jax.Array  # (..., k) int32
    universe: int = dataclasses.field(metadata=dict(static=True), default=0)

    def tree_flatten(self):
        return (self.values, self.indices), (self.universe,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def _raw_bits(self) -> int:
        k = int(self.values.shape[-1])
        return k * (_dtype_bits(self.values) + _dtype_bits(self.indices))

    def _entropy_bits(self) -> Optional[int]:
        if not self.universe:
            return None
        k = int(self.values.shape[-1])
        return (k * _dtype_bits(self.values)
                + _entropy_index_bits(k, self.universe))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class BlockSparsePayload(Payload):
    """k (value, in-tile flat index) pairs per (block x block) tile, tiles
    in row-major grid order — the Pallas block_topk kernel's native
    output format."""

    values: jax.Array   # (..., nblocks, k)
    indices: jax.Array  # (..., nblocks, k) int32
    universe: int = dataclasses.field(metadata=dict(static=True), default=0)
    # ^ addressable slots per tile (block^2)

    def tree_flatten(self):
        return (self.values, self.indices), (self.universe,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def _raw_bits(self) -> int:
        nblk, k = (int(s) for s in self.values.shape[-2:])
        return nblk * k * (_dtype_bits(self.values)
                           + _dtype_bits(self.indices))

    def _entropy_bits(self) -> Optional[int]:
        if not self.universe:
            return None
        nblk, k = (int(s) for s in self.values.shape[-2:])
        return nblk * (k * _dtype_bits(self.values)
                       + _entropy_index_bits(k, self.universe))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class LowRankPayload(Payload):
    """Rank-R factors: dense = (left * middle) @ right.T (eigh/SVD style,
    middle of size r) or (left @ right.T) * middle[0] (PowerSGD, middle a
    single rescale float). No index stream (see ``Payload.bits``)."""

    left: jax.Array    # (..., d0, r)
    right: jax.Array   # (..., d1, r)
    middle: jax.Array  # (..., r) eigen/singular values, or (..., 1) scale

    def tree_flatten(self):
        return (self.left, self.right, self.middle), None

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def _raw_bits(self) -> int:
        d0, r = (int(s) for s in self.left.shape[-2:])
        d1 = int(self.right.shape[-2])
        mid = int(self.middle.shape[-1])
        return (d0 * r + d1 * r + mid) * _dtype_bits(self.left)


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DensePayload(Payload):
    """A dense array shipped as-is. ``count`` is the number of entries
    charged on the wire and ``indexed`` whether each also ships an index
    — Bernoulli sparsification stores its (dense-layout) masked values
    here but is charged its *expected* occupancy int(p * numel), the one
    documented payload whose measured bits are an expectation rather
    than a per-draw count (occupancy is a random variate, so a static
    wire size cannot equal it draw-by-draw; the codec, which may be
    data-dependent, encodes the *actual* occupied slots)."""

    values: jax.Array
    count: int = dataclasses.field(metadata=dict(static=True), default=0)
    indexed: bool = dataclasses.field(metadata=dict(static=True), default=False)
    universe: int = dataclasses.field(metadata=dict(static=True), default=0)

    def tree_flatten(self):
        return (self.values,), (self.count, self.indexed, self.universe)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)

    def _raw_bits(self) -> int:
        vbits = self.count * _dtype_bits(self.values)
        if not self.indexed:
            return vbits
        return vbits + self.count * INDEX_BITS

    def _entropy_bits(self) -> Optional[int]:
        if not (self.indexed and self.universe):
            return None
        return (self.count * _dtype_bits(self.values)
                + _entropy_index_bits(self.count, self.universe))


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class DitheredPayload(Payload):
    """Random-dithering wire object: one q-norm float plus, per entry, a
    sign bit and a quantization level in {0..s}. Levels/signs are stored
    as (integer-valued) floats for exact reconstruction; ``bits()``
    charges the paper's encoded width 1 + ceil(log2(s+1)) per entry.
    Dense level stream — no index stream (see ``Payload.bits``)."""

    norm: jax.Array     # (..., 1)
    signs: jax.Array    # (..., *shape)
    levels: jax.Array   # (..., *shape), integer-valued in [0, s]
    s: int = dataclasses.field(metadata=dict(static=True), default=1)
    count: int = dataclasses.field(metadata=dict(static=True), default=0)

    def tree_flatten(self):
        return (self.norm, self.signs, self.levels), (self.s, self.count)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def _raw_bits(self) -> int:
        level_bits = max(1, math.ceil(math.log2(self.s + 1)))
        return _dtype_bits(self.norm) + self.count * (1 + level_bits)


def _scatter_flat(values, indices, n: int) -> jax.Array:
    """Dense (n,) vector from (value, index) pairs; -1/out-of-range
    indices (payload padding) are dropped. Negative indices must be
    remapped BEFORE the scatter: jax normalizes them (−1 → n−1) before
    the bounds check, so mode="drop" alone would overwrite the last
    entry instead of dropping the padding."""
    indices = jnp.where(indices < 0, n, indices)
    return jnp.zeros((n,), values.dtype).at[indices].set(values, mode="drop")


def scale_payload(payload, w: jax.Array):
    """Reweight per-silo contributions of a STACKED payload (leading
    silo axis): returns a payload whose decoded dense matrices are
    ``w_i * decompress(payload_i)``. Zero weight removes a silo from
    ``Compressor.aggregate`` — the partial-participation mask. The
    scale multiplies the one leaf each wire format is linear in
    (values; low-rank middle; dithering signs).

    Documented alias: ``Compressor.aggregate(payloads, shape,
    weights=w)`` applies this internally — pass weights there instead
    of composing the two calls by hand (the no-deprecated-accessor
    analysis rule flags the old ``aggregate(scale_payload(...))``
    composition). The standalone form stays for payload-level uses that
    never reach an aggregate (e.g. wire experiments)."""
    if isinstance(payload, LowRankPayload):
        field = "middle"
    elif isinstance(payload, DitheredPayload):
        field = "signs"
    else:
        field = "values"
    leaf = getattr(payload, field)
    w = jnp.asarray(w, leaf.dtype)
    wb = w.reshape(w.shape + (1,) * (leaf.ndim - w.ndim))
    return dataclasses.replace(payload, **{field: leaf * wb})


def _should_stream(vals, idx) -> bool:
    """Stream the silo axis from host memory once the stacked pair
    stream outgrows the kernel VMEM budget. Only concrete arrays can
    stream (a traced aggregate — inside jit/vmap/eval_shape — keeps the
    stacked kernel, whose BlockSpecs already bound VMEM per program;
    what streaming bounds is the *device-resident stack*, which only
    exists for concrete cross-device-scale inputs)."""
    from ..kernels import VMEM_BUDGET_BYTES

    if isinstance(vals, jax.core.Tracer):
        return False
    if not isinstance(vals, (np.ndarray, jax.Array)):
        return False  # ShapeDtypeStruct etc. — trace-only callers
    n, k = vals.shape
    pair = jnp.dtype(vals.dtype).itemsize + jnp.dtype(idx.dtype).itemsize
    return n * k * pair > VMEM_BUDGET_BYTES


def _sparse_aggregate(payloads: "SparsePayload", shape,
                      symmetric: bool = False) -> jax.Array:
    """mean_i of stacked SparsePayloads via ONE dense accumulator
    (kernels/scatter_accum: Pallas one-hot-matmul scatter on TPU —
    single-block or output-tiled by VMEM budget, so any d — a single
    XLA scatter-add elsewhere). -1 padding is dropped; duplicate
    indices across silos accumulate — exactly the server sum.
    ``symmetric`` mirrors lower-triangular payloads inside the same
    scatter pass (the fused symmetric-TopK server mean). Concrete
    stacks whose (value, index) pair stream outgrows the VMEM budget
    are streamed silo-slab by silo-slab instead (bitwise equal —
    kernels/scatter_accum/ops.py)."""
    from ..kernels.scatter_accum import (
        scatter_accumulate,
        streamed_scatter_accumulate,
    )

    n = payloads.values.shape[0]
    shape2 = tuple(int(s) for s in shape)
    if len(shape2) != 2:  # vectors (downlink model payloads) etc.
        shape2 = (1, numel(shape))
        symmetric = False
    if _should_stream(payloads.values, payloads.indices):
        total = streamed_scatter_accumulate(payloads.values,
                                            payloads.indices, shape2,
                                            symmetric=symmetric)
    else:
        total = scatter_accumulate(payloads.values, payloads.indices,
                                   shape2, symmetric=symmetric)
    return (total / n).reshape(shape)


def _lowrank_aggregate(payloads: "LowRankPayload", shape) -> jax.Array:
    """mean_i (left_i * middle_i) @ right_i^T by stacking factors: one
    batched matmul contracting over (silo, rank) — never per-silo dense
    matrices. ``middle`` broadcasts for both wire layouts: (n, r)
    eigen/singular values and (n, 1) PowerSGD rescale."""
    left, right, mid = payloads.left, payloads.right, payloads.middle
    n = left.shape[0]
    return jnp.einsum("nir,njr->ij", left * mid[:, None, :], right) / n


# ---------------------------------------------------------------------------
# CompSpec and the base class
# ---------------------------------------------------------------------------


class CompSpec(NamedTuple):
    """Analytic class parameters of a compressor at a given shape.

    Exactly one of delta (Def 3.3) / omega (Def 3.2) is set; ``bits`` is
    the analytic uplink size the paper's x-axis charges (clamped to what
    the payload can actually contain); ``deterministic`` selects the
    stepsize assumption (3.4 vs 3.5)."""

    delta: Optional[float]
    omega: Optional[float]
    bits: int
    deterministic: bool


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A compression operator as a wire codec with analytic accounting.

    Subclasses implement ``compress``/``decompress``/``spec``; the dense
    ``__call__`` is always ``decompress(compress(...))``.

    ``wire_is_dense`` marks families whose payload carries one slot per
    matrix entry (identity, natural, dithering): their stacked payloads
    ARE (n, d, d)-sized by design, so the no-dense-silo-stack analysis
    rule does not apply to them."""

    wire_is_dense = False  # plain class attr, NOT a dataclass field

    def compress(self, m: jax.Array, key: Optional[jax.Array] = None):
        raise NotImplementedError

    def decompress(self, payload, shape) -> jax.Array:
        raise NotImplementedError

    def __call__(self, m: jax.Array, key: Optional[jax.Array] = None) -> jax.Array:
        return self.decompress(self.compress(m, key), m.shape)

    def aggregate(self, payloads, shape, weights=None) -> jax.Array:
        """Server-side mean over silos, straight from payload space.

        ``payloads`` is a STACKED payload pytree with a leading silo
        axis (the output of ``jax.vmap(self.compress)``); returns the
        dense ``mean_i w_i * decompress(payload_i, shape)`` as ONE
        (d, d) array. ``weights`` is an optional (n,) per-silo scale
        applied in payload space (``scale_payload``) BEFORE the
        reduction — the partial-participation mask and the cohort
        layer's staleness weights in one place; every override inherits
        it through this same pre-scale, so weighting is uniform across
        wire formats. This generic fallback decompresses-then-means
        (the only place an (n, d, d) stack is ever allowed on the
        server); subclasses override with structure-aware accumulation
        that never materializes it. Equivalence is pinned per
        registered family by tests/test_aggregate.py (f64 tolerance —
        reduction order differs)."""
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        dec = jax.vmap(lambda p: self.decompress(p, shape))(payloads)
        return jnp.mean(dec, axis=0)

    def spec(self, shape) -> CompSpec:
        raise NotImplementedError

    def bits(self, shape) -> int:
        """Analytic wire bits for one application (= spec(shape).bits).

        DEPRECATED alias: prefer ``repro.wire.wire_cost(comp,
        shape).analytic_bits``, which returns this number alongside the
        measured/entropy/encoded ones in a single ``WireReport``."""
        return self.spec(shape).bits

    def encode(self, payload, value_format: str = "raw") -> bytes:
        """Serialize ONE payload of this compressor to wire bytes via
        the bitstream codec (dispatch lives in ``repro.wire.codec``,
        keyed on the payload family)."""
        from ..wire.codec import encode as _encode

        return _encode(payload, value_format=value_format)

    def decode(self, data: bytes, shape=None):
        """Deserialize wire bytes back into this compressor's payload
        (host numpy arrays; feed to ``decompress`` as-is or via jnp)."""
        from ..wire.codec import decode as _decode

        return _decode(data, shape=shape)


def payload_bits(comp: Compressor, shape, dtype=None,
                 index_coding: str = "raw") -> int:
    """MEASURED wire bits of one payload: build the payload's structure
    with ``jax.eval_shape`` (no FLOPs) and ask it. This is the number a
    real serializer would put on the wire for the ambient dtype —
    compare with ``comp.spec(shape).bits``, the paper's analytic claim
    at FLOAT_BITS=64. ``index_coding="entropy"`` swaps the raw 32-bit
    index streams for their log2 C(universe, k) information cost
    (payloads without an index stream are unchanged).

    DEPRECATED alias: prefer ``repro.wire.wire_cost(comp, shape)``,
    whose ``raw_bits`` / ``entropy_bits`` fields are exactly this
    function at the two index codings (and whose ``encoded_bytes`` is
    the real codec's output, which this estimate approximates)."""
    if dtype is None:
        dtype = jnp.result_type(float)
    m = jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))
    key = jax.ShapeDtypeStruct((2,), jnp.dtype(jnp.uint32))
    pay = jax.eval_shape(comp.compress, m, key)
    return int(pay.bits(index_coding=index_coding))


# ---------------------------------------------------------------------------
# Registry — string-keyed, self-registering (mirrors the Method registry)
# ---------------------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Compressor]] = {}


def _canon(name: str) -> str:
    return name.replace("-", "").replace("_", "").lower()


def register_compressor(*names: str):
    """Decorator: register ``factory(level) -> Compressor`` under every
    name in ``names`` (spelling-insensitive: "-"/"_"/case ignored).
    Re-registration overwrites (last wins) so notebooks can hot-patch."""

    def deco(factory):
        for n in names:
            _REGISTRY[_canon(n)] = factory
        return factory

    return deco


def available_compressors() -> list[str]:
    return sorted(_REGISTRY)


def registered_compressors() -> dict[str, Callable[..., Compressor]]:
    """Snapshot of the compressor registry (canonical name -> factory) —
    the introspection hook the static-analysis sweep (``repro.analysis``)
    enumerates. Spelling aliases share a factory object, so callers can
    deduplicate families by factory identity."""
    return dict(_REGISTRY)


def make_compressor(family: str, level=None) -> Compressor:
    """String-keyed compressor factory: ("rankr", 1) -> RankR(1), etc.

    Families: rankr, topk, powersgd, randk, dithering, blocktopk,
    blocktopkthreshold, natural, identity, zero. ``level`` is the
    family's knob (rank, k, s, p, ...); identity/zero take none.
    """
    fam = _canon(family)
    if fam not in _REGISTRY:
        raise ValueError(
            f"unknown compressor family {family!r}; "
            f"known: {available_compressors()}")
    return _REGISTRY[fam](level)


# ---------------------------------------------------------------------------
# Contractive compressors  C(delta)  — Def 3.3
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TopK(Compressor):
    """Global Top-K over all entries (paper A.3.3). delta = K / numel.

    ``symmetric=True`` applies the operator to the lower triangle only
    and mirrors it (the paper's symmetry-preserving variant); K then
    counts kept lower-triangular entries and the payload contains only
    the lower-triangular pairs it actually ships.
    """

    k: int
    symmetric: bool = False

    def _slots(self, shape) -> int:
        """Entries the payload can meaningfully address (K clamps here:
        a Top-K larger than the matrix ships the matrix, not more)."""
        if self.symmetric and len(shape) == 2 and shape[0] == shape[1]:
            return shape[0] * (shape[0] + 1) // 2
        return numel(shape)

    def compress(self, m: jax.Array, key=None) -> SparsePayload:
        sym = self.symmetric and m.ndim == 2 and m.shape[0] == m.shape[1]
        flat = (jnp.tril(m) if sym else m).reshape(-1)
        k = min(self.k, self._slots(m.shape))
        _, idx = jax.lax.top_k(jnp.abs(flat), k)
        return SparsePayload(values=flat[idx], indices=idx.astype(jnp.int32),
                             universe=self._slots(m.shape))

    def decompress(self, payload: SparsePayload, shape) -> jax.Array:
        c = _scatter_flat(payload.values, payload.indices,
                          numel(shape)).reshape(shape)
        if self.symmetric and len(shape) == 2 and shape[0] == shape[1]:
            return c + c.T - jnp.diag(jnp.diag(c))
        return c

    def aggregate(self, payloads: SparsePayload, shape,
                  weights=None) -> jax.Array:
        """Scatter-add all n*k (value, index) pairs into ONE dense
        accumulator, then mean. The symmetric mirror is FUSED into the
        scatter itself (each off-diagonal pair lands at (r, c) and
        (c, r) in the same kernel pass) instead of a second
        ``c + c.T - diag(diag(c))`` sweep over the dense accumulator —
        mirroring is linear, so it commutes with the mean. Never builds
        the (n, d, d) stack."""
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        sym = self.symmetric and len(shape) == 2 and shape[0] == shape[1]
        return _sparse_aggregate(payloads, shape, symmetric=sym)

    def spec(self, shape) -> CompSpec:
        slots = self._slots(shape)
        k = min(self.k, slots)  # clamp: no overcount on small problems
        return CompSpec(delta=k / slots, omega=None,
                        bits=k * (FLOAT_BITS + INDEX_BITS),
                        deterministic=True)


def _to_tiles(m: jax.Array, b: int):
    """(d0, d1) -> (n0*n1, b*b) row-major tiles, zero-padded."""
    d0, d1 = m.shape
    p0, p1 = (-d0) % b, (-d1) % b
    mp = jnp.pad(m, ((0, p0), (0, p1)))
    n0, n1 = mp.shape[0] // b, mp.shape[1] // b
    return mp.reshape(n0, b, n1, b).transpose(0, 2, 1, 3).reshape(n0 * n1, b * b)


def _from_tiles(tiles: jax.Array, shape, b: int) -> jax.Array:
    d0, d1 = shape
    n0, n1 = -(-d0 // b), -(-d1 // b)
    out = tiles.reshape(n0, n1, b, b).transpose(0, 2, 1, 3) \
        .reshape(n0 * b, n1 * b)
    return out[:d0, :d1]


@dataclasses.dataclass(frozen=True)
class _BlockSparse(Compressor):
    """Shared decode + accounting for the block-local Top-K family: the
    payload is per-tile (values, in-tile flat indices) in row-major grid
    order — the Pallas kernel's native format (kernels/block_topk
    ``block_topk_payload``). Subclasses supply the selection rule."""

    k_per_block: int
    block: int = 128

    def _k(self) -> int:
        return min(self.k_per_block, self.block * self.block)

    def decompress(self, payload: BlockSparsePayload, shape) -> jax.Array:
        b = self.block
        nblk = payload.values.shape[-2]
        # -1 padding -> out-of-range BEFORE the scatter (jax normalizes
        # negative indices ahead of the mode="drop" bounds check)
        idx = jnp.where(payload.indices < 0, b * b, payload.indices)
        out = jnp.zeros((nblk, b * b), payload.values.dtype)
        out = jax.vmap(lambda o, i, v: o.at[i].set(v, mode="drop"))(
            out, idx, payload.values)
        return _from_tiles(out, shape, b)

    def aggregate(self, payloads: BlockSparsePayload, shape,
                  weights=None,
                  use_pallas: Optional[bool] = None) -> jax.Array:
        """Per-tile scatter-add of all n silos' pairs into ONE tiled
        accumulator (kernels/scatter_accum block kernel on TPU), then
        crop and mean — tiles are disjoint, so the tile-local sums ARE
        the dense sum. ``use_pallas`` threads through to the kernel
        dispatch (None = auto by backend); fednl_precond uses it to
        pin its jaxpr-inspected TPU path."""
        from ..kernels.scatter_accum import block_scatter_accumulate

        if weights is not None:
            payloads = scale_payload(payloads, weights)
        b = self.block
        n = payloads.values.shape[0]
        gm, gn = -(-int(shape[0]) // b), -(-int(shape[1]) // b)
        total = block_scatter_accumulate(payloads.values, payloads.indices,
                                         (gm, gn), b, use_pallas=use_pallas)
        return total[:shape[0], :shape[1]] / n

    def spec(self, shape) -> CompSpec:
        b = self.block
        nblk = -(-shape[0] // b) * -(-shape[1] // b)
        return CompSpec(delta=self._k() / (b * b), omega=None,
                        bits=nblk * self._k() * (FLOAT_BITS + INDEX_BITS),
                        deterministic=True)

    def fused_diff_payloads(self, h_new: jax.Array, h_old: jax.Array):
        """Fused device uplink for stacked (n, d, d) Hessian pairs:
        per silo, D_i = h_new_i - h_old_i is diffed, top-k-selected,
        and payload-emitted inside ONE kernel (``diff_topk_payload``)
        that also returns ||D_i||_F^2 — the dense difference never
        round-trips through HBM on the Pallas path, and the l_i every
        FedNL variant ships comes out of the same pass. Returns
        (stacked BlockSparsePayload, (n,) Frobenius norms). Selection
        semantics match ``compress``'s family contract: identical to
        the sort-based reference off-TPU, bisection flat-order inside
        tie clusters on the kernel path."""
        from ..kernels.block_topk import diff_topk_payload

        vals, idx, sq = jax.vmap(
            lambda a, b: diff_topk_payload(a, b, k=self._k(),
                                           block=self.block))(h_new, h_old)
        payloads = BlockSparsePayload(values=vals, indices=idx,
                                      universe=self.block * self.block)
        return payloads, jnp.sqrt(sq)


@dataclasses.dataclass(frozen=True)
class BlockTopK(_BlockSparse):
    """TPU-native block-local Top-K: keep the top ``k_per_block`` entries
    of every (b x b) tile. Contractive with delta = k_per_block / b^2
    (the contraction inequality holds per tile and the Frobenius norm is
    separable over tiles). This class is the pure-jnp reference
    semantics (sort-based selection)."""

    def compress(self, m: jax.Array, key=None) -> BlockSparsePayload:
        tiles = _to_tiles(m, self.block)
        _, idx = jax.lax.top_k(jnp.abs(tiles), self._k())
        vals = jnp.take_along_axis(tiles, idx, axis=1)
        return BlockSparsePayload(values=vals, indices=idx.astype(jnp.int32),
                                  universe=self.block * self.block)


@dataclasses.dataclass(frozen=True)
class BlockTopKThreshold(_BlockSparse):
    """Block-local Top-K via threshold bisection — the pure-jnp mirror of
    the Pallas kernel (kernels/block_topk). Selection by ~32 rounds of
    compare+count instead of a sort: O(iters * n) vector ops vs
    O(n log n) scalar-ish sort work, which matters when the compressor
    runs inside every optimizer step (second_order/fednl_precond).

    Keeps EXACTLY k entries per tile: every entry strictly above the
    bisection bracket, then boundary ties (entries inside the final
    [lo, hi) bracket, equal to within the f32 bisection resolution) in
    flat order until k slots fill. This preserves the Def 3.3
    contraction at delta = k_per_block / block^2 even when a tie
    cluster spans the k-th position — a threshold-only cut (ax >= hi)
    can keep arbitrarily fewer than k there and break the inequality
    ``spec()`` reports."""

    iters: int = 32

    def _bracket(self, ax: jax.Array):
        """Per-tile bisection bracket (lo, hi) on |x| with
        count(ax >= hi) <= k <= count(ax >= lo)."""
        k = self._k()
        hi = jnp.max(ax, axis=1)
        lo = jnp.zeros_like(hi)

        def body(_, lohi):
            lo, hi = lohi
            mid = 0.5 * (lo + hi)
            cnt = jnp.sum(ax >= mid[:, None], axis=1)
            too_many = cnt > k
            return jnp.where(too_many, mid, lo), jnp.where(too_many, hi, mid)

        return jax.lax.fori_loop(0, self.iters, body, (lo, hi))

    def compress(self, m: jax.Array, key=None) -> BlockSparsePayload:
        tiles = _to_tiles(m, self.block)
        nblk, bb = tiles.shape
        k = self._k()
        ax = jnp.abs(tiles).astype(jnp.float32)
        lo, hi = self._bracket(ax)
        strict = ax >= hi[:, None]                      # count <= k
        tie = (ax >= lo[:, None]) & ~strict             # strict+tie >= k
        # sort-free compaction (cumsum + scatter, O(bb) like the Pallas
        # kernel): strict survivors first, then ties in flat order; tie
        # overflow beyond k and non-survivors scatter out of range
        n_strict = jnp.sum(strict, axis=1, keepdims=True)
        slot = jnp.where(
            strict, jnp.cumsum(strict, axis=1) - 1,
            jnp.where(tie, n_strict + jnp.cumsum(tie, axis=1) - 1, k))
        rows = jnp.arange(nblk)[:, None]
        vals = jnp.zeros((nblk, k), tiles.dtype) \
            .at[rows, slot].set(tiles, mode="drop")
        idx = jnp.full((nblk, k), -1, jnp.int32) \
            .at[rows, slot].set(jnp.arange(bb, dtype=jnp.int32)[None, :],
                                mode="drop")
        return BlockSparsePayload(values=vals, indices=idx,
                                  universe=self.block * self.block)


@dataclasses.dataclass(frozen=True)
class RankR(Compressor):
    """Exact Rank-R truncation (paper A.3.2). delta = R/d. Deterministic.

    ``symmetric=True`` (default — every matrix FedNL compresses is a
    Hessian difference): the rank-R approximation of M = Q diag(lam) Q^T
    keeps the R largest-|lam| eigenpairs, computed with eigh. This is
    exactly A.3.2's symmetric case (output sum sigma_i u_i u_i^T) and is
    numerically robust where batched divide-and-conquer SVD (gesdd) can
    emit NaNs inside fused XLA:CPU programs. ``symmetric=False`` uses the
    general SVD. The payload ships both factors plus the R values — the
    paper's sigma + u + v accounting (the symmetric case could ship u
    once; we charge the paper's number).
    """

    r: int
    symmetric: bool = True

    def compress(self, m: jax.Array, key=None) -> LowRankPayload:
        if self.symmetric:
            sym = 0.5 * (m + m.T)
            lam, q = jnp.linalg.eigh(sym)
            r = min(self.r, lam.shape[0])
            _, idx = jax.lax.top_k(jnp.abs(lam), r)
            return LowRankPayload(left=q[:, idx], right=q[:, idx],
                                  middle=lam[idx])
        u, s, vt = jnp.linalg.svd(m, full_matrices=False)
        r = min(self.r, s.shape[0])
        return LowRankPayload(left=u[:, :r], right=vt[:r, :].T, middle=s[:r])

    def decompress(self, payload: LowRankPayload, shape) -> jax.Array:
        return (payload.left * payload.middle) @ payload.right.T

    def aggregate(self, payloads: LowRankPayload, shape,
                  weights=None) -> jax.Array:
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        return _lowrank_aggregate(payloads, shape)

    def spec(self, shape) -> CompSpec:
        r = min(self.r, min(shape))
        return CompSpec(delta=r / min(shape), omega=None,
                        bits=r * FLOAT_BITS * (1 + shape[0] + shape[1]),
                        deterministic=True)


def _orthonormalize(q: jax.Array) -> jax.Array:
    """Gram-Schmidt via QR; matmul-heavy, TPU friendly."""
    qq, _ = jnp.linalg.qr(q)
    return qq


@dataclasses.dataclass(frozen=True)
class PowerSGD(Compressor):
    """PowerSGD-style rank-R approximation via ``iters`` rounds of subspace
    iteration (Vogels et al. 2019; benchmarked by the paper in Fig. 3/5).

    Scaled per Definition 3.3's remark so ||C(M)||_F <= ||M||_F always
    holds; with enough iterations this approaches RankR (the reported
    delta is the Rank-R bound — conservative: one power iteration
    already dominates a random subspace's energy capture). Deterministic
    given the fixed seed for the starting subspace. The payload ships
    the two factors plus the contraction-preserving rescale float.
    """

    r: int
    iters: int = 2
    seed: int = 0

    def compress(self, m: jax.Array, key=None) -> LowRankPayload:
        d1 = m.shape[1]
        q = jax.random.normal(jax.random.PRNGKey(self.seed), (d1, self.r),
                              m.dtype)
        q = _orthonormalize(q)
        for _ in range(self.iters):
            p = _orthonormalize(m @ q)          # (d0, r)
            q = _orthonormalize(m.T @ p)        # (d1, r)
        p = m @ q                                # un-normalized left factor
        # contraction-preserving rescale (Def 3.3 remark)
        num = jnp.linalg.norm(m)
        den = jnp.linalg.norm(p @ q.T)
        scale = jnp.minimum(1.0, num / jnp.maximum(den, 1e-30))
        return LowRankPayload(left=p, right=q, middle=scale[None])

    def decompress(self, payload: LowRankPayload, shape) -> jax.Array:
        return (payload.left @ payload.right.T) * payload.middle[0]

    def aggregate(self, payloads: LowRankPayload, shape,
                  weights=None) -> jax.Array:
        # (L_i @ R_i^T) * mid_i[0] == (L_i * mid_i) @ R_i^T — same
        # stacked-factor contraction as RankR
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        return _lowrank_aggregate(payloads, shape)

    def spec(self, shape) -> CompSpec:
        r = min(self.r, min(shape))
        return CompSpec(delta=r / min(shape), omega=None,
                        bits=self.r * FLOAT_BITS * (shape[0] + shape[1])
                        + FLOAT_BITS,  # + the rescale float
                        deterministic=True)


@dataclasses.dataclass(frozen=True)
class Identity(Compressor):
    """C = I (classical Newton's communication)."""

    wire_is_dense = True

    def compress(self, m: jax.Array, key=None) -> DensePayload:
        return DensePayload(values=m, count=numel(m.shape), indexed=False)

    def decompress(self, payload: DensePayload, shape) -> jax.Array:
        return payload.values.reshape(shape)

    def aggregate(self, payloads: DensePayload, shape,
                  weights=None) -> jax.Array:
        # the wire IS dense: the mean over the stacked wire values is
        # the server reduction itself (no decompress round-trip)
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        n = payloads.values.shape[0]
        return jnp.mean(payloads.values.reshape((n,) + tuple(shape)), axis=0)

    def spec(self, shape) -> CompSpec:
        return CompSpec(delta=1.0, omega=None,
                        bits=numel(shape) * FLOAT_BITS, deterministic=True)


@dataclasses.dataclass(frozen=True)
class Zero(Compressor):
    """C = 0 (Newton-Zero / Newton-Star corner of the Newton triangle).
    The payload is empty — zero measured bits by construction."""

    def compress(self, m: jax.Array, key=None) -> SparsePayload:
        return SparsePayload(values=m.reshape(-1)[:0],
                             indices=jnp.zeros((0,), jnp.int32),
                             universe=numel(m.shape))

    def decompress(self, payload: SparsePayload, shape) -> jax.Array:
        return _scatter_flat(payload.values, payload.indices,
                             numel(shape)).reshape(shape)

    def aggregate(self, payloads: SparsePayload, shape,
                  weights=None) -> jax.Array:
        return jnp.zeros(shape, payloads.values.dtype)  # w * 0 == 0

    def spec(self, shape) -> CompSpec:
        return CompSpec(delta=0.0, omega=None, bits=0, deterministic=True)


# ---------------------------------------------------------------------------
# Unbiased compressors  B(omega)  — Def 3.2
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RandK(Compressor):
    """Rand-K with numel/K rescale (paper A.3.4). omega = numel/K - 1.
    The rescale is folded into the payload values (the server never
    needs K separately)."""

    k: int
    symmetric: bool = False

    def compress(self, m: jax.Array, key: jax.Array = None) -> SparsePayload:
        assert key is not None, "RandK is randomized; pass a PRNG key"
        flat = m.reshape(-1)
        n = flat.shape[0]
        k = min(self.k, n)
        idx = jax.random.choice(key, n, (k,), replace=False)
        return SparsePayload(values=flat[idx] * (n / k),
                             indices=idx.astype(jnp.int32), universe=n)

    def decompress(self, payload: SparsePayload, shape) -> jax.Array:
        return _scatter_flat(payload.values, payload.indices,
                             numel(shape)).reshape(shape)

    def aggregate(self, payloads: SparsePayload, shape,
                  weights=None) -> jax.Array:
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        return _sparse_aggregate(payloads, shape)

    def spec(self, shape) -> CompSpec:
        n = numel(shape)
        k = min(self.k, n)  # clamp: no overcount on small problems
        return CompSpec(delta=None, omega=n / k - 1.0,
                        bits=k * (FLOAT_BITS + INDEX_BITS),
                        deterministic=False)


@dataclasses.dataclass(frozen=True)
class RandomDithering(Compressor):
    """Random dithering with s levels, q-norm (paper A.3.1; used for
    DIANA/ADIANA on vectors). omega <= min(d/s^2, sqrt(d)/s) for q=2.
    """

    s: int
    q: float = 2.0
    wire_is_dense = True

    def compress(self, x: jax.Array, key: jax.Array = None) -> DitheredPayload:
        assert key is not None
        norm = jnp.linalg.norm(x.reshape(-1), ord=self.q)
        norm = jnp.maximum(norm, 1e-30)
        y = jnp.abs(x) / norm * self.s          # in [0, s]
        low = jnp.floor(y)
        prob = y - low
        bump = jax.random.bernoulli(key, prob, x.shape).astype(x.dtype)
        return DitheredPayload(norm=norm[None], signs=jnp.sign(x),
                               levels=low + bump, s=self.s,
                               count=numel(x.shape))

    def decompress(self, payload: DitheredPayload, shape) -> jax.Array:
        norm = payload.norm[0]
        levels = payload.levels / self.s
        out = payload.signs * norm * levels
        return jnp.where(norm > 1e-29, out, jnp.zeros_like(out)).reshape(shape)

    def aggregate(self, payloads: DitheredPayload, shape,
                  weights=None) -> jax.Array:
        # direct mean of the elementwise decode: the dithered wire is
        # already dense-sized (a level per entry), so vmapped decode +
        # mean IS the payload-space reduction — one decode
        # implementation, no extra dense intermediates beyond the wire
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        dec = jax.vmap(lambda p: self.decompress(p, shape))(payloads)
        return jnp.mean(dec, axis=0)

    def spec(self, shape) -> CompSpec:
        n = numel(shape)
        level_bits = max(1, math.ceil(math.log2(self.s + 1)))
        return CompSpec(
            delta=None,
            omega=min(n / self.s**2, math.sqrt(n) / self.s),
            bits=FLOAT_BITS + n * (1 + level_bits),  # norm + sign+level/entry
            deterministic=False)


@dataclasses.dataclass(frozen=True)
class NaturalSparsification(Compressor):
    """Bernoulli(p) sparsification with 1/p rescale — unbiased,
    omega = 1/p - 1. Used by FedNL-BC's uplink gradient scheme analysis
    and as a generic cheap unbiased operator. Payload occupancy is a
    random variate; measured bits charge the expectation int(p*numel)
    (see DensePayload)."""

    p: float
    wire_is_dense = True

    def compress(self, x: jax.Array, key: jax.Array = None) -> DensePayload:
        assert key is not None
        mask = jax.random.bernoulli(key, self.p, x.shape)
        # where(), not x*mask/p: the masked-out entries must be clean
        # +0.0, or the codec's bit-level occupancy test charges -0.0
        # slots for every dropped negative entry.
        return DensePayload(values=jnp.where(mask, x / self.p, 0.0),
                            count=int(self.p * numel(x.shape)), indexed=True,
                            universe=numel(x.shape))

    def decompress(self, payload: DensePayload, shape) -> jax.Array:
        return payload.values.reshape(shape)

    def aggregate(self, payloads: DensePayload, shape,
                  weights=None) -> jax.Array:
        if weights is not None:
            payloads = scale_payload(payloads, weights)
        n = payloads.values.shape[0]
        return jnp.mean(payloads.values.reshape((n,) + tuple(shape)), axis=0)

    def spec(self, shape) -> CompSpec:
        return CompSpec(
            delta=None, omega=1.0 / self.p - 1.0,
            bits=int(self.p * numel(shape)) * (FLOAT_BITS + INDEX_BITS),
            deterministic=False)


# ---------------------------------------------------------------------------
# Registry entries (string key -> factory(level))
# ---------------------------------------------------------------------------


@register_compressor("rankr", "rank")
def _make_rankr(level):
    return RankR(int(level))


@register_compressor("topk")
def _make_topk(level):
    return TopK(k=int(level))


@register_compressor("topk-sym")
def _make_topk_sym(level):
    return TopK(k=int(level), symmetric=True)


@register_compressor("powersgd")
def _make_powersgd(level):
    return PowerSGD(r=int(level), iters=2)


@register_compressor("randk")
def _make_randk(level):
    return RandK(k=int(level))


@register_compressor("dithering", "random-dithering")
def _make_dithering(level):
    return RandomDithering(s=int(level))


@register_compressor("blocktopk")
def _make_blocktopk(level):
    return BlockTopK(k_per_block=int(level))


@register_compressor("blocktopk-threshold")
def _make_blocktopk_threshold(level):
    return BlockTopKThreshold(k_per_block=int(level))


@register_compressor("natural")
def _make_natural(level):
    return NaturalSparsification(p=float(level))


@register_compressor("identity", "none")
def _make_identity(level):
    return Identity()


@register_compressor("zero")
def _make_zero(level):
    return Zero()


# ---------------------------------------------------------------------------
# Stepsize rules (Assumptions 3.4 / 3.5 and the constants (A, B) of eq. (5))
# ---------------------------------------------------------------------------


def alpha_for(comp: Compressor, shape, rule: str = "auto") -> float:
    """Theoretical Hessian learning rate for a compressor.

    rule = 'one'        -> alpha = 1               (Assumption 3.4(ii))
    rule = 'contract'   -> alpha = 1 - sqrt(1-delta)  (Assumption 3.4(i))
    rule = 'unbiased'   -> alpha = 1/(omega+1)     (Assumption 3.5)
    rule = 'auto'       -> 'one' for contractive, 'unbiased' otherwise
    """
    sp = comp.spec(shape)
    if rule == "auto":
        rule = "one" if sp.deterministic else "unbiased"
    if rule == "one":
        return 1.0
    if rule == "contract":
        assert sp.delta is not None
        return 1.0 - (1.0 - sp.delta) ** 0.5
    if rule == "unbiased":
        assert sp.omega is not None
        return 1.0 / (sp.omega + 1.0)
    raise ValueError(rule)


def ab_constants(comp: Compressor, shape, alpha: float) -> tuple[float, float]:
    """(A, B) of eq. (5), selecting the assumption matching (comp, alpha)."""
    sp = comp.spec(shape)
    if sp.deterministic:
        if alpha == 1.0:
            return sp.delta / 4.0, 6.0 / sp.delta - 3.5
        return alpha**2, alpha
    return alpha, alpha
