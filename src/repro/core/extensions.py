"""Beyond-paper extensions addressing the paper's own stated Limitations
(Appendix I):

* "We do not consider stochastic gradient or stochastic Hessian oracles"
  -> ``StochasticFedNL``: FedNL with per-round subsampled local Hessians
  (exact gradients, minibatch Hessians — the Newton-sketching regime).
  The Hessian-learning rule needs no modification: the compressed
  difference now chases a noisy target, and with alpha <= 1/(omega+1)-
  style damping the estimates converge to a noise-floor neighborhood of
  hess_i(x*); empirically (tests/test_extensions.py) the iterates still
  reach gaps ~ the Hessian-subsampling noise floor in a handful of
  rounds.

* "We do not design a single master method containing all these
  extensions" -> ``FedNLPPBC``: partial participation (Algorithm 2's
  Hessian-corrected local gradients and server-side diff aggregation)
  combined with smart downlink model compression (Algorithm 5's learned
  broadcast model z^{k+1} = z^k + eta C_M(x^{k+1} - z^k)). Active silos
  only ever see the learned model z — so BOTH directions are compressed
  AND only tau silos participate per round.

These are labeled beyond-paper: no theory is claimed here beyond the
paper's; the tests validate empirical convergence.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS, Compressor
from .fednl import FedNLState
from .linalg import frob_norm, solve_newton_system


class StochasticFedNL(MethodBase):
    """FedNL (Option 2) with stochastic local Hessian oracles.

    hess_fn(x, key) -> (n, d, d) subsampled local Hessians;
    grad_fn(x) exact (the paper's regime of interest keeps gradients
    exact; pass a stochastic one if desired).
    ``alpha`` should be damped (e.g. 0.25-0.5) — the compressed
    difference chases a noisy target.
    """

    def __init__(self, grad_fn, hess_fn_stoch, compressor: Compressor,
                 alpha: float = 0.5):
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn_stoch
        self.comp = compressor
        self.alpha = alpha

    def init(self, x0, n, key=None, seed: int = 0) -> FedNLState:
        if key is None:
            key = jax.random.PRNGKey(seed)
        h0 = self.hess_fn(x0, key)
        return FedNLState(x=x0, h_local=h0, h_global=jnp.mean(h0, axis=0),
                          key=key, step=jnp.zeros((), jnp.int32))

    def step(self, state: FedNLState) -> FedNLState:
        n = state.h_local.shape[0]
        d = state.x.shape[0]
        key, k_h, k_c = jax.random.split(state.key, 3)
        silo_keys = jax.random.split(k_c, n)

        grads = self.grad_fn(state.x)
        hesses = self.hess_fn(state.x, k_h)          # noisy local Hessians
        payloads, l_i = self._uplink_diff_payloads(hesses, state.h_local,
                                                   silo_keys)
        s_i = self._local_hessians(payloads, hesses.shape[1:])

        grad = jnp.mean(grads, axis=0)
        l_mean = jnp.mean(l_i)
        h_eff = state.h_global + l_mean * jnp.eye(d, dtype=state.x.dtype)
        x_new = state.x - solve_newton_system(h_eff, grad)

        return FedNLState(
            x=x_new,
            h_local=state.h_local + self.alpha * s_i,
            h_global=state.h_global + self.alpha * self._server_aggregate(
                payloads, hesses.shape[1:]),
            key=key, step=state.step + 1,
        )

    def bits_per_round(self, d: int) -> int:
        """Uplink per device: gradient + S_i + l_i (as FedNL Option 2).
        Measured counterpart comes from MethodBase (same layout)."""
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        return d * FLOAT_BITS + s_bits + FLOAT_BITS


class FedNLPPBCState(NamedTuple):
    z: jax.Array         # (d,) learned broadcast model (all silos hold this)
    w: jax.Array         # (n, d) per-silo last-participation models
    h_local: jax.Array   # (n, d, d)
    l_local: jax.Array   # (n,)
    g_local: jax.Array   # (n, d) Hessian-corrected local gradients
    h_global: jax.Array
    l_global: jax.Array
    g_global: jax.Array
    x: jax.Array         # server's uncompressed iterate (monitoring)
    key: jax.Array
    step: jax.Array


class FedNLPPBC(MethodBase):
    """Master method: FedNL-PP x FedNL-BC (beyond paper).

    Round structure:
      server: x^{k+1} = (H + l I)^{-1} g        (Alg 2 line 4)
              s = C_M(x^{k+1} - z);  z <- z + eta s     (Alg 5 downlink)
              sample S^k, |S^k| = tau
      active silos (receive only the compressed s): evaluate at z,
              H_i <- H_i + alpha C(hess_i(z) - H_i)
              l_i  = ||H_i - hess_i(z)||_F
              g_i  = (H_i + l_i I) z - grad_i(z)        (Alg 2 line 12)
              uplink: compressed Hessian diff + (l, g) diffs
      server aggregates diffs (Alg 2 lines 18-20).
    """

    traj_field = "z"
    silo_fields = ("w", "h_local", "l_local", "g_local")

    def __init__(self, grad_fn, hess_fn, compressor: Compressor,
                 model_compressor: Compressor, tau: int,
                 alpha: float = 1.0, eta: float = 1.0):
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.comp = compressor
        self.comp_m = model_compressor
        self.tau = tau
        self.alpha = alpha
        self.eta = eta

    def init(self, x0, n, seed: int = 0) -> FedNLPPBCState:
        d = x0.shape[0]
        h0 = self.hess_fn(x0)
        l0 = jnp.zeros((n,))
        grads = self.grad_fn(x0)
        eye = jnp.eye(d, dtype=x0.dtype)
        g0 = jax.vmap(lambda h, l, gi: (h + l * eye) @ x0 - gi)(h0, l0, grads)
        return FedNLPPBCState(
            z=x0, w=jnp.tile(x0[None], (n, 1)), h_local=h0, l_local=l0,
            g_local=g0, h_global=jnp.mean(h0, axis=0), l_global=jnp.mean(l0),
            g_global=jnp.mean(g0, axis=0), x=x0,
            key=jax.random.PRNGKey(seed), step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: FedNLPPBCState) -> FedNLPPBCState:
        n, d = state.w.shape
        key, k_sel, k_comp, k_m = jax.random.split(state.key, 4)
        eye = jnp.eye(d, dtype=state.z.dtype)

        # server: Newton-type step from aggregates, then compressed broadcast
        h_eff = state.h_global + state.l_global * eye
        x_new = solve_newton_system(h_eff, state.g_global)
        down_payload = self.comp_m.compress(x_new - state.z, k_m)
        s_model = self.comp_m.decompress(down_payload, (d,))
        z_new = state.z + self.eta * s_model

        # participation
        perm = jax.random.permutation(k_sel, n)
        active = jnp.zeros((n,), bool).at[perm[: self.tau]].set(True)

        # active-silo updates, evaluated at the learned model z_new
        silo_keys = jax.random.split(k_comp, n)
        hess_z = self.hess_fn(z_new)
        grads_z = self.grad_fn(z_new)
        payloads, _ = self._uplink_diff_payloads(hess_z, state.h_local,
                                                silo_keys)
        s_i = self._local_hessians(payloads, (d, d))
        h_upd = state.h_local + self.alpha * s_i
        l_upd = jax.vmap(frob_norm)(h_upd - hess_z)
        g_upd = jax.vmap(lambda h, l, gi: (h + l * eye) @ z_new - gi)(
            h_upd, l_upd, grads_z)

        mask, maskm = active[:, None], active[:, None, None]
        return FedNLPPBCState(
            z=z_new,
            w=jnp.where(mask, z_new[None], state.w),
            h_local=jnp.where(maskm, h_upd, state.h_local),
            l_local=jnp.where(active, l_upd, state.l_local),
            g_local=jnp.where(mask, g_upd, state.g_local),
            h_global=state.h_global + self.alpha * self._server_aggregate(
                payloads, (d, d), weights=active.astype(state.z.dtype)),
            l_global=state.l_global + jnp.mean(
                jnp.where(active, l_upd - state.l_local, 0.0)),
            g_global=state.g_global + jnp.mean(
                jnp.where(mask, g_upd - state.g_local, 0.0), axis=0),
            x=x_new, key=key, step=state.step + 1,
        )

    def bits_per_round(self, d: int) -> tuple[int, int]:
        """(uplink per active silo, downlink broadcast). Analytic."""
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        up = s_bits + FLOAT_BITS + d * FLOAT_BITS
        down = wire_cost(self.comp_m, (d,), encoded=False).analytic_bits
        return up, down

    def measured_bits_per_round(self, d: int,
                                index_coding: str = "raw") -> tuple[int, int]:
        """Overrides the MethodBase default: bidirectional wire."""
        from ..wire.report import wire_cost
        from .compressors import canonical_float_bits

        fb = canonical_float_bits()
        pick = lambda rep: (rep.entropy_bits if index_coding == "entropy"
                            else rep.raw_bits)
        up = pick(wire_cost(self.comp, (d, d), encoded=False)) + fb + d * fb
        down = pick(wire_cost(self.comp_m, (d,), encoded=False))
        return up, down


@register("fednl-stoch")
def _make_fednl_stoch(oracles: Oracles, compressor, hess_fn_stoch=None,
                      **params):
    if hess_fn_stoch is None:  # degenerate: exact Hessians, key ignored
        hess_fn_stoch = lambda x, key: oracles.hess(x)
    return StochasticFedNL(oracles.grad, hess_fn_stoch, compressor, **params)


@register("fednl-ppbc")
def _make_fednl_ppbc(oracles: Oracles, compressor, model_compressor, **params):
    return FedNLPPBC(oracles.grad, oracles.hess, compressor, model_compressor,
                     **params)
