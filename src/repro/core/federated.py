"""Distributed execution of FedNL over a JAX mesh.

The paper's communication pattern (devices -> server -> devices) maps to:

* the silo dimension of the DATA sharded over a mesh axis (default
  "data") — each device holds its silos' (a, b) slabs and Hessian
  estimates H_i, and computes purely locally;
* "send compressed update to server" = ``lax.pmean`` over that axis;
* "broadcast x^{k+1}" = the replicated output of the collective.

``run_fednl_sharded`` builds the per-shard oracles from the local data
slab inside ``shard_map``, so no device ever touches another silo's
training data — the paper's [pe] privacy posture holds structurally, not
just in accounting. Works on any mesh whose axis divides the silo count,
including a single-device mesh (trivial collectives), so the same code
path runs in CI and on a pod.

Byte accounting: the paper's bits-per-round metric is analytic
(``FedNL.bits_per_round``); inside one pod the all-reduce moves dense
tiles and is what §Roofline measures for the LM-scale adaptation.
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from .compressors import Compressor
from .fednl import FedNL, FedNLState
from .objectives import LogRegData, silo_grad, silo_hess


def run_fednl_sharded(data: LogRegData, compressor: Compressor, mesh: Mesh,
                      x0: jax.Array, num_rounds: int, alpha: float = 1.0,
                      option: int = 2, mu: float = 0.0, axis: str = "data",
                      seed: int = 0):
    """FedNL with silos sharded over ``mesh[axis]``. Returns
    (final state with sharded h_local, (rounds+1, d) iterate history)."""
    n = data.a.shape[0]
    lam = data.lam

    def local_oracles(a, b):
        grad_fn = lambda x: jax.vmap(lambda aa, bb: silo_grad(x, aa, bb, lam))(a, b)
        hess_fn = lambda x: jax.vmap(lambda aa, bb: silo_hess(x, aa, bb, lam))(a, b)
        return grad_fn, hess_fn

    state_specs = FedNLState(x=P(), h_local=P(axis), h_global=P(), key=P(),
                             step=P())

    @partial(_shard_map, mesh=mesh,
             in_specs=(state_specs, P(axis), P(axis)),
             out_specs=state_specs)
    def sharded_step(state: FedNLState, a, b) -> FedNLState:
        grad_fn, hess_fn = local_oracles(a, b)
        alg = FedNL(grad_fn, hess_fn, compressor, alpha=alpha, option=option,
                    mu=mu, axis_name=axis)
        return alg.step(state)

    # global init (exact local Hessians at x0), then shard
    grad_all = lambda x: jax.vmap(lambda aa, bb: silo_grad(x, aa, bb, lam))(
        data.a, data.b)
    hess_all = lambda x: jax.vmap(lambda aa, bb: silo_hess(x, aa, bb, lam))(
        data.a, data.b)
    alg0 = FedNL(grad_all, hess_all, compressor, alpha=alpha, option=option,
                 mu=mu)
    state = alg0.init(x0, n, seed=seed)

    shard = lambda arr, spec: jax.device_put(arr, NamedSharding(mesh, spec))
    state = state._replace(h_local=shard(state.h_local, P(axis)))
    a_sh = shard(data.a, P(axis))
    b_sh = shard(data.b, P(axis))

    step = jax.jit(sharded_step)
    xs = [x0]
    for _ in range(num_rounds):
        state = step(state, a_sh, b_sh)
        xs.append(state.x)
    return state, jnp.stack(xs)
