"""FedNL — Algorithm 1 (Federated Newton Learn), faithful implementation.

One communication round (paper Sec. 3):

  devices i = 1..n in parallel:
      receive x^k
      S_i^k = C_i^k(H2_i(x^k) - H_i^k)             # compressed Hessian diff
      l_i^k = ||H_i^k - H2_i(x^k)||_F              # one float
      send  grad_i(x^k), S_i^k, l_i^k
      H_i^{k+1} = H_i^k + alpha S_i^k
  server:
      grad = mean_i grad_i ; S = mean_i S_i ; l = mean_i l_i
      H^{k+1} = H^k + alpha S
      Option 1: x^{k+1} = x^k - [H^k]_mu^{-1} grad
      Option 2: x^{k+1} = x^k - (H^k + l^k I)^{-1} grad

The implementation is a pure jittable step over *stacked* per-silo state,
so the same code runs (a) single-process via vmap, and (b) sharded over a
mesh axis via shard_map (see core/federated.py). The device uplink is an
explicit wire object: each silo builds a compressed ``Payload``, keeps
its OWN dense S_i for the local H_i update, and the server computes
S = mean_i S_i *in payload space* (``Compressor.aggregate`` — one dense
(d, d) accumulator, no per-silo decompression server-side). Communicated
bits are *measured* from the payload structure
(``measured_bits_per_round``) next to the paper's analytic accounting
(``bits_per_round``).
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import Compressor
from .linalg import project_psd, solve_newton_system


class FedNLState(NamedTuple):
    x: jax.Array        # (d,) global model
    h_local: jax.Array  # (n, d, d) local Hessian estimates H_i
    h_global: jax.Array  # (d, d) server estimate H = mean_i H_i
    key: jax.Array      # PRNG for randomized compressors
    step: jax.Array     # iteration counter


class FedNL(MethodBase):
    """Vanilla FedNL. ``option`` in {1, 2}; ``mu`` needed for Option 1.

    grad_fn:  x -> (n, d) stacked per-silo gradients
    hess_fn:  x -> (n, d, d) stacked per-silo Hessians
    """

    def __init__(
        self,
        grad_fn: Callable[[jax.Array], jax.Array],
        hess_fn: Callable[[jax.Array], jax.Array],
        compressor: Compressor,
        alpha: float = 1.0,
        option: int = 1,
        mu: float = 0.0,
        axis_name: Optional[str] = None,
    ):
        """``axis_name``: when set, the step is written for execution under
        ``shard_map`` with the silo dimension sharded over that mesh axis —
        per-silo math runs on the local slab and "send to server" becomes a
        ``lax.pmean`` over the axis (the TPU-idiomatic server)."""
        assert option in (1, 2)
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.comp = compressor
        self.alpha = alpha
        self.option = option
        self.mu = mu
        self.axis_name = axis_name

    def _mean(self, v: jax.Array) -> jax.Array:
        m = jnp.mean(v, axis=0)
        if self.axis_name is not None:
            m = jax.lax.pmean(m, self.axis_name)
        return m

    # -- state ---------------------------------------------------------------

    def init(self, x0: jax.Array, n: int, h0: Optional[jax.Array] = None,
             seed: int = 0) -> FedNLState:
        """h0: (n,d,d) initial local estimates; default = exact local
        Hessians at x0 (the paper's initialization for FedNL)."""
        if h0 is None:
            h0 = self.hess_fn(x0)
        h0 = jnp.asarray(h0)
        return FedNLState(
            x=x0,
            h_local=h0,
            h_global=jnp.mean(h0, axis=0),
            key=jax.random.PRNGKey(seed),
            step=jnp.zeros((), jnp.int32),
        )

    # -- one communication round ----------------------------------------------

    def step(self, state: FedNLState) -> FedNLState:
        n = state.h_local.shape[0]
        key, sub = jax.random.split(state.key)
        if self.axis_name is not None:
            sub = jax.random.fold_in(sub, jax.lax.axis_index(self.axis_name))
        silo_keys = jax.random.split(sub, n)

        grads = self.grad_fn(state.x)                     # (n, d)
        hesses = self.hess_fn(state.x)                    # (n, d, d)

        # devices uplink payloads of D_i = hess_i - H_i (fused
        # diff->select->payload where the compressor supports it, so the
        # dense diff stays in VMEM); each silo keeps its OWN dense S_i
        # for the local H_i update, the server means in payload space —
        # the (n, d, d) decompressed stack never reaches the server
        payloads, l_i = self._uplink_diff_payloads(hesses, state.h_local,
                                                   silo_keys)
        s_i = self._local_hessians(payloads, hesses.shape[1:])

        grad = self._mean(grads)
        s_mean = self._server_aggregate(payloads, hesses.shape[1:])
        l_mean = self._mean(l_i)

        h_global = state.h_global + self.alpha * s_mean
        h_local = state.h_local + self.alpha * s_i

        # Model update uses the *current* H^k (paper lines 11-12 use H^k).
        if self.option == 1:
            h_eff = project_psd(state.h_global, self.mu)
        else:
            d = state.x.shape[0]
            h_eff = state.h_global + l_mean * jnp.eye(d, dtype=state.x.dtype)
        x_new = state.x - solve_newton_system(h_eff, grad)

        return FedNLState(x_new, h_local, h_global, key, state.step + 1)

    # -- communication accounting ----------------------------------------------

    def bits_per_round(self, d: int) -> int:
        """ANALYTIC uplink bits per device per round: gradient + S_i + l_i
        (the paper's x-axis, FLOAT_BITS-denominated)."""
        from ..wire.report import wire_cost
        from .compressors import FLOAT_BITS

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        return d * FLOAT_BITS + s_bits + FLOAT_BITS

    # measured_bits_per_round comes from MethodBase: payload structure
    # (jax.eval_shape) + (d + 1) ambient floats — the same layout.

    def init_bits(self, d: int) -> int:
        """The paper counts the cost of shipping H_i^0 = hess(x0) once."""
        from .compressors import FLOAT_BITS

        return d * (d + 1) // 2 * FLOAT_BITS  # symmetric matrix

    # The round loop (``run``) comes from MethodBase: lax.scan of ``step``
    # recording ``x``, with x0 prepended.


@register("fednl")
def _make_fednl(oracles: Oracles, compressor, **params):
    return FedNL(oracles.grad, oracles.hess, compressor, **params)
