"""FedNL-BC — Algorithm 5 (bidirectional compression).

Uplink:   Bernoulli(p) gradient rounds — when xi^k = 1 devices send true
          gradients at the learned model z^k; otherwise the server uses
          Hessian-corrected surrogates g_i = H_i^k (z^k - w^k) + grad_i(w^k)
          built from the last synced gradient point w^k. Hessian diffs are
          compressed every round as in FedNL.
Downlink: "smart" model learning — the server sends only the compressed
          model increment s^k = C_M(x^{k+1} - z^k); everyone tracks
          z^{k+1} = z^k + eta s^k.

State follows the paper exactly: z (learned model), w (last gradient-sync
point), H_i, H, and the Bernoulli flag xi synchronized by the server.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS, Compressor
from .linalg import project_psd, solve_newton_system


class FedNLBCState(NamedTuple):
    z: jax.Array         # (d,) learned global model (devices + server)
    w: jax.Array         # (d,) last gradient-sync model
    grad_w: jax.Array    # (n, d) per-silo gradients at w (device cache)
    h_local: jax.Array   # (n, d, d)
    h_global: jax.Array  # (d, d)
    xi: jax.Array        # () bool — current Bernoulli flag
    x: jax.Array         # (d,) server's uncompressed iterate (monitoring)
    key: jax.Array
    step: jax.Array


class FedNLBC(MethodBase):
    traj_field = "z"  # devices only ever hold the learned model z
    silo_fields = ("grad_w", "h_local")

    def __init__(
        self,
        grad_fn: Callable[[jax.Array], jax.Array],   # x -> (n, d)
        hess_fn: Callable[[jax.Array], jax.Array],   # x -> (n, d, d)
        compressor: Compressor,                      # device Hessian compressor
        model_compressor: Compressor,                # server downlink C_M
        p: float = 1.0,                              # gradient sync probability
        alpha: float = 1.0,
        eta: float = 1.0,
        option: int = 1,
        mu: float = 0.0,
    ):
        assert option in (1, 2)
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.comp = compressor
        self.comp_m = model_compressor
        self.p = p
        self.alpha = alpha
        self.eta = eta
        self.option = option
        self.mu = mu

    def init(self, x0: jax.Array, n: int, seed: int = 0) -> FedNLBCState:
        h0 = self.hess_fn(x0)
        return FedNLBCState(
            z=x0, w=x0, grad_w=self.grad_fn(x0),
            h_local=h0, h_global=jnp.mean(h0, axis=0),
            xi=jnp.ones((), bool), x=x0,
            key=jax.random.PRNGKey(seed), step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: FedNLBCState) -> FedNLBCState:
        n = state.h_local.shape[0]
        d = state.z.shape[0]
        key, k_comp, k_m, k_xi = jax.random.split(state.key, 4)
        silo_keys = jax.random.split(k_comp, n)

        # --- devices -----------------------------------------------------
        grad_z = self.grad_fn(state.z)                       # used when xi=1
        g_corr = jax.vmap(lambda h, gw: h @ (state.z - state.w) + gw)(
            state.h_local, state.grad_w)                     # used when xi=0
        g_i = jnp.where(state.xi, grad_z, g_corr)
        w_new = jnp.where(state.xi, state.z, state.w)
        grad_w_new = jnp.where(state.xi, grad_z, state.grad_w)

        hess_z = self.hess_fn(state.z)
        payloads, l_i = self._uplink_diff_payloads(hess_z, state.h_local,
                                                   silo_keys)
        s_i = self._local_hessians(payloads, hess_z.shape[1:])

        # --- server --------------------------------------------------------
        g = jnp.mean(g_i, axis=0)
        l_mean = jnp.mean(l_i)
        if self.option == 1:
            h_eff = project_psd(state.h_global, self.mu)
        else:
            h_eff = state.h_global + l_mean * jnp.eye(d, dtype=state.z.dtype)
        x_new = state.z - solve_newton_system(h_eff, g)

        h_local = state.h_local + self.alpha * s_i
        h_global = state.h_global + self.alpha * self._server_aggregate(
            payloads, hess_z.shape[1:])

        # downlink: the server broadcasts the compressed model increment
        # as a wire payload; every device decompresses and learns z
        down_payload = self.comp_m.compress(x_new - state.z, k_m)
        s_model = self.comp_m.decompress(down_payload, (d,))
        z_new = state.z + self.eta * s_model

        xi_new = jax.random.bernoulli(k_xi, self.p)

        return FedNLBCState(z_new, w_new, grad_w_new, h_local, h_global,
                            xi_new, x_new, key, state.step + 1)

    def bits_per_round(self, d: int) -> tuple[float, int]:
        """(expected uplink bits per device, downlink bits). Analytic."""
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        m_bits = wire_cost(self.comp_m, (d,), encoded=False).analytic_bits
        up = self.p * d * FLOAT_BITS + s_bits + FLOAT_BITS
        down = m_bits + 1  # model increment + xi bit
        return up, down

    def measured_bits_per_round(self, d: int,
                                index_coding: str = "raw") -> tuple[float, int]:
        """Measured counterpart (overrides the MethodBase default: this
        wire is bidirectional): uplink/downlink payload structure sizes
        via jax.eval_shape over both compressors' payloads."""
        from ..wire.report import wire_cost
        from .compressors import canonical_float_bits

        fb = canonical_float_bits()
        pick = lambda rep: (rep.entropy_bits if index_coding == "entropy"
                            else rep.raw_bits)
        up = (self.p * d * fb
              + pick(wire_cost(self.comp, (d, d), encoded=False))
              + fb)
        down = pick(wire_cost(self.comp_m, (d,), encoded=False)) + 1
        return up, down


@register("fednl-bc")
def _make_fednl_bc(oracles: Oracles, compressor, model_compressor, **params):
    return FedNLBC(oracles.grad, oracles.hess, compressor, model_compressor,
                   **params)
