"""FedNL-CR — Algorithm 4 (globalization via cubic regularization).

Same device-side Hessian learning as FedNL. Server solves

  h^k = argmin_h <grad, h> + 1/2 <(H^k + l^k I) h, h> + (L*/6) ||h||^3

(the l^k correction makes H^k + l^k I an upper bound on the true Hessian,
giving a global cubic upper model — paper Sec. 4.3/E) and steps
x^{k+1} = x^k + h^k. H_i^0 = 0 is the paper's initialization for CR.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS, Compressor
from .fednl import FedNLState
from .linalg import solve_cubic_subproblem


class FedNLCR(MethodBase):
    def __init__(
        self,
        grad_fn: Callable[[jax.Array], jax.Array],
        hess_fn: Callable[[jax.Array], jax.Array],
        compressor: Compressor,
        l_star: float,
        alpha: float = 1.0,
    ):
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.comp = compressor
        self.l_star = l_star
        self.alpha = alpha

    def init(self, x0, n, h0=None, seed: int = 0) -> FedNLState:
        d = x0.shape[0]
        if h0 is None:
            h0 = jnp.zeros((n, d, d), x0.dtype)  # paper: H_i^0 = 0 for CR
        return FedNLState(
            x=x0, h_local=h0, h_global=jnp.mean(h0, axis=0),
            key=jax.random.PRNGKey(seed), step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: FedNLState) -> FedNLState:
        n = state.h_local.shape[0]
        key, sub = jax.random.split(state.key)
        silo_keys = jax.random.split(sub, n)

        grads = self.grad_fn(state.x)
        hesses = self.hess_fn(state.x)
        payloads, l_i = self._uplink_diff_payloads(hesses, state.h_local,
                                                   silo_keys)
        s_i = self._local_hessians(payloads, hesses.shape[1:])

        grad = jnp.mean(grads, axis=0)
        l_mean = jnp.mean(l_i)
        d = state.x.shape[0]
        h_corr = state.h_global + l_mean * jnp.eye(d, dtype=state.x.dtype)

        h_step = solve_cubic_subproblem(grad, h_corr, self.l_star)
        x_new = state.x + h_step

        return FedNLState(
            x=x_new,
            h_local=state.h_local + self.alpha * s_i,
            h_global=state.h_global + self.alpha * self._server_aggregate(
                payloads, hesses.shape[1:]),
            key=key,
            step=state.step + 1,
        )

    def bits_per_round(self, d: int) -> int:
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        return d * FLOAT_BITS + s_bits + FLOAT_BITS


@register("fednl-cr")
def _make_fednl_cr(oracles: Oracles, compressor, **params):
    return FedNLCR(oracles.grad, oracles.hess, compressor, **params)
