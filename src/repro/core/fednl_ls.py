"""FedNL-LS — Algorithm 3 (globalization via backtracking line search).

Identical Hessian learning to FedNL; the server fixes the direction
d^k = -[H^k]_mu^{-1} grad f(x^k) and backtracks gamma^s until
f(x^k + gamma^s d^k) <= f(x^k) + c gamma^s <grad, d^k>.
Devices additionally report f_i(x^k) (one float) so the server can
evaluate f along the ray — the paper notes this extra communication is
negligible; we charge FLOAT_BITS per probe per device in accounting.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS, Compressor
from .fednl import FedNLState
from .linalg import project_psd, solve_newton_system
from .newton import backtracking


class FedNLLS(MethodBase):
    def __init__(
        self,
        value_fn: Callable[[jax.Array], jax.Array],   # x -> global f(x)
        grad_fn: Callable[[jax.Array], jax.Array],    # x -> (n, d)
        hess_fn: Callable[[jax.Array], jax.Array],    # x -> (n, d, d)
        compressor: Compressor,
        alpha: float = 1.0,
        mu: float = 0.0,
        c: float = 0.5,
        gamma: float = 0.5,
    ):
        self.value_fn = value_fn
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn
        self.comp = compressor
        self.alpha = alpha
        self.mu = mu
        self.c = c
        self.gamma = gamma

    def init(self, x0, n, h0=None, seed: int = 0) -> FedNLState:
        if h0 is None:
            h0 = self.hess_fn(x0)
        return FedNLState(
            x=x0, h_local=h0, h_global=jnp.mean(h0, axis=0),
            key=jax.random.PRNGKey(seed), step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: FedNLState) -> FedNLState:
        n = state.h_local.shape[0]
        key, sub = jax.random.split(state.key)
        silo_keys = jax.random.split(sub, n)

        grads = self.grad_fn(state.x)
        hesses = self.hess_fn(state.x)
        payloads, _ = self._uplink_diff_payloads(hesses, state.h_local,
                                                silo_keys)
        s_i = self._local_hessians(payloads, hesses.shape[1:])

        grad = jnp.mean(grads, axis=0)
        h_eff = project_psd(state.h_global, self.mu)
        d_dir = -solve_newton_system(h_eff, grad)
        t = backtracking(self.value_fn, state.x, d_dir, grad,
                         c=self.c, gamma=self.gamma)
        x_new = state.x + t * d_dir

        return FedNLState(
            x=x_new,
            h_local=state.h_local + self.alpha * s_i,
            h_global=state.h_global + self.alpha * self._server_aggregate(
                payloads, hesses.shape[1:]),
            key=key,
            step=state.step + 1,
        )

    def bits_per_round(self, d: int) -> int:
        # f_i + gradient + S_i
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        return FLOAT_BITS + d * FLOAT_BITS + s_bits

    def init_bits(self, d: int) -> int:
        """H_i^0 = hess_i(x0) shipped once (as in FedNL)."""
        return d * (d + 1) // 2 * FLOAT_BITS


@register("fednl-ls")
def _make_fednl_ls(oracles: Oracles, compressor, **params):
    return FedNLLS(oracles.value, oracles.grad, oracles.hess, compressor,
                   **params)
