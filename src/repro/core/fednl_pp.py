"""FedNL-PP — Algorithm 2 (partial participation).

Server state: g^k = mean_i g_i^k, H^k = mean_i H_i^k, l^k = mean_i l_i^k.
Every round:

  x^{k+1} = (H^k + l^k I)^{-1} g^k                      # line 4
  sample S^k subset of [n], |S^k| = tau, uniformly       # line 5
  participating i:  w_i <- x^{k+1}
                    H_i <- H_i + alpha C(hess_i(w_i) - H_i)
                    l_i <- ||H_i - hess_i(w_i)||_F
                    g_i <- (H_i + l_i I) w_i - grad_i(w_i)   # Hessian-corrected
  non-participating: frozen.
  server keeps g, H, l consistent via the communicated diffs (lines 18-20).

The Hessian-corrected local gradient g_i = (H_i + l_i I) w_i - grad_i(w_i)
is the paper's key trick: it turns the server aggregate into an implicit
Newton-type step on *stale* local models. Note the sign conventions:
x^{k+1} = (H + lI)^{-1} g with g as defined — the server step is line 4.
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS, Compressor
from .linalg import frob_norm, solve_newton_system


class FedNLPPState(NamedTuple):
    w: jax.Array         # (n, d) stale local models
    h_local: jax.Array   # (n, d, d)
    l_local: jax.Array   # (n,)
    g_local: jax.Array   # (n, d) Hessian-corrected local gradients
    h_global: jax.Array  # (d, d)
    l_global: jax.Array  # ()
    g_global: jax.Array  # (d,)
    x: jax.Array         # (d,) latest global model (for monitoring)
    key: jax.Array
    step: jax.Array


class FedNLPP(MethodBase):
    silo_fields = ("w", "h_local", "l_local", "g_local")

    def __init__(
        self,
        grad_fn_at: Callable[[jax.Array], jax.Array],   # x -> (n, d) per-silo grads at x
        hess_fn_at: Callable[[jax.Array], jax.Array],   # x -> (n, d, d)
        compressor: Compressor,
        tau: int,
        alpha: float = 1.0,
    ):
        self.grad_fn = grad_fn_at
        self.hess_fn = hess_fn_at
        self.comp = compressor
        self.tau = tau
        self.alpha = alpha

    def init(self, x0: jax.Array, n: int, seed: int = 0) -> FedNLPPState:
        d = x0.shape[0]
        w = jnp.tile(x0[None], (n, 1))
        h0 = self.hess_fn(x0)                                  # H_i^0 = hess_i(x0)
        hess_w = h0
        l0 = jax.vmap(frob_norm)(h0 - hess_w)                  # zeros
        grads = self.grad_fn(x0)
        g0 = jax.vmap(lambda h, l, wi, gi: (h + l * jnp.eye(d, dtype=x0.dtype)) @ wi - gi)(
            h0, l0, w, grads)
        return FedNLPPState(
            w=w, h_local=h0, l_local=l0, g_local=g0,
            h_global=jnp.mean(h0, axis=0), l_global=jnp.mean(l0),
            g_global=jnp.mean(g0, axis=0), x=x0,
            key=jax.random.PRNGKey(seed), step=jnp.zeros((), jnp.int32),
        )

    def step(self, state: FedNLPPState) -> FedNLPPState:
        n, d = state.w.shape
        key, k_sel, k_comp = jax.random.split(state.key, 3)

        # line 4: global model from server aggregates
        h_eff = state.h_global + state.l_global * jnp.eye(d, dtype=state.x.dtype)
        x_new = solve_newton_system(h_eff, state.g_global)

        # line 5: uniform subset of size tau
        perm = jax.random.permutation(k_sel, n)
        active = jnp.zeros((n,), bool).at[perm[: self.tau]].set(True)

        # device updates (computed for all, applied where active)
        silo_keys = jax.random.split(k_comp, n)
        hess_new = self.hess_fn(x_new)                         # hess_i(w_i^{k+1}=x^{k+1})
        grads_new = self.grad_fn(x_new)

        payloads, _ = self._uplink_diff_payloads(hess_new, state.h_local,
                                                silo_keys)
        s_i = self._local_hessians(payloads, (d, d))
        h_upd = state.h_local + self.alpha * s_i
        l_upd = jax.vmap(frob_norm)(h_upd - hess_new)
        eye = jnp.eye(d, dtype=state.x.dtype)
        g_upd = jax.vmap(lambda h, l, gi: (h + l * eye) @ x_new - gi)(h_upd, l_upd, grads_new)

        mask = active[:, None]
        maskm = active[:, None, None]
        w_next = jnp.where(mask, x_new[None], state.w)
        h_next = jnp.where(maskm, h_upd, state.h_local)
        l_next = jnp.where(active, l_upd, state.l_local)
        g_next = jnp.where(mask, g_upd, state.g_local)

        # server lines 18-20: aggregate diffs from active clients — the
        # Hessian diffs arrive as payloads and are meaned in payload
        # space, masked by zero-weighting inactive silos (a zero weight
        # zeroes that silo's decoded contribution exactly)
        h_global = state.h_global + self.alpha * self._server_aggregate(
            payloads, (d, d), weights=active.astype(state.x.dtype))
        l_global = state.l_global + jnp.mean(jnp.where(active, l_upd - state.l_local, 0.0))
        g_global = state.g_global + jnp.mean(
            jnp.where(mask, g_upd - state.g_local, 0.0), axis=0)

        return FedNLPPState(w_next, h_next, l_next, g_next,
                            h_global, l_global, g_global, x_new, key, state.step + 1)

    def bits_per_round(self, d: int) -> int:
        """Per *active* device: S_i + (l diff) + (g diff). Analytic; the
        measured counterpart comes from MethodBase (same layout)."""
        from ..wire.report import wire_cost

        s_bits = wire_cost(self.comp, (d, d), encoded=False).analytic_bits
        return s_bits + FLOAT_BITS + d * FLOAT_BITS


@register("fednl-pp")
def _make_fednl_pp(oracles: Oracles, compressor, **params):
    return FedNLPP(oracles.grad, oracles.hess, compressor, **params)
