"""Linear-algebra substrate for the FedNL family.

* ``project_psd`` — [X]_mu, projection onto {M = M^T, M >= mu I}
  (paper A.4, eqs. (19)-(20)).
* ``solve_newton_system`` — stable solve for the (projected/corrected)
  Newton step.
* ``solve_cubic_subproblem`` — argmin <g,h> + 1/2 <(H+lI)h, h> + (L/6)||h||^3
  by reduction to a 1-D secular equation on the eigenbasis (paper E.2).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def symmetrize(m: jax.Array) -> jax.Array:
    return 0.5 * (m + m.T)


def project_psd(m: jax.Array, mu: float = 0.0) -> jax.Array:
    """[X]_mu := [X - mu I]_0 + mu I with [Y]_0 clipping eigenvalues at 0."""
    sym = symmetrize(m)
    d = sym.shape[0]
    eye = jnp.eye(d, dtype=sym.dtype)
    evals, evecs = jnp.linalg.eigh(sym - mu * eye)
    clipped = jnp.maximum(evals, 0.0)
    return (evecs * clipped) @ evecs.T + mu * eye


def solve_newton_system(h: jax.Array, g: jax.Array) -> jax.Array:
    """Solve H x = g for symmetric (assumed PD) H via Cholesky with an
    LU fallback baked in numerically (jnp.linalg.solve is LAPACK gesv on
    CPU and a triangular solve pipeline on TPU)."""
    return jnp.linalg.solve(h, g)


def solve_cubic_subproblem(
    g: jax.Array,
    h_mat: jax.Array,
    m_cubic: float,
    iters: int = 100,
) -> jax.Array:
    """argmin_h T(h) = <g,h> + 1/2 h^T H h + (M/6) ||h||^3.

    Stationarity: (H + (M/2)||h|| I) h = -g. Let r = ||h||; in the
    eigenbasis of H = Q diag(lam) Q^T, with b = Q^T g:

        phi(r) = sum_i b_i^2 / (lam_i + (M/2) r)^2 - r^2 = 0

    phi is decreasing in r for r >= r_min where all denominators are
    positive; we bisect on r in [r_lo, r_hi]. H may be indefinite —
    cubic regularization handles that; we start the bracket at
    r_lo = max(0, -2 lam_min / M) + eps. The Moré–Sorensen "hard case"
    (g orthogonal to the bottom eigenvector with an interior boundary
    solution) is approximated by the bracket endpoint, which is accurate
    to the bisection tolerance — sufficient for FedNL-CR, whose theory
    only needs T(h) <= 0 = T(0) (descent on the cubic model).
    """
    lam, q = jnp.linalg.eigh(symmetrize(h_mat))
    b = q.T @ g
    m_half = m_cubic / 2.0

    lam_min = lam[0]
    r_lo = jnp.maximum(0.0, -2.0 * lam_min / m_cubic) + 1e-12
    # upper bound: ||h|| <= r with (M/2) r^2 >= ||g|| + |lam_min| r
    gnorm = jnp.linalg.norm(g)
    r_hi = (jnp.abs(lam_min) + jnp.sqrt(lam_min**2 + 2.0 * m_cubic * gnorm)) / m_cubic + 1.0

    def phi(r):
        denom = lam + m_half * r
        denom = jnp.where(jnp.abs(denom) < 1e-30, 1e-30, denom)
        return jnp.sum((b / denom) ** 2) - r**2

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        val = phi(mid)
        lo = jnp.where(val > 0, mid, lo)
        hi = jnp.where(val > 0, hi, mid)
        return lo, hi

    r_lo, r_hi = jax.lax.fori_loop(0, iters, body, (r_lo, r_hi))
    r = 0.5 * (r_lo + r_hi)

    denom = lam + m_half * r
    denom = jnp.where(jnp.abs(denom) < 1e-30, 1e-30, denom)
    h = -(q @ (b / denom))
    # Degenerate case g = 0: h = 0 is the minimizer when H is PSD.
    return jnp.where(gnorm > 1e-30, h, jnp.zeros_like(h))


def frob_norm(m: jax.Array) -> jax.Array:
    return jnp.sqrt(jnp.sum(m * m))
