"""Reference Newton-type methods: N, NS, N0, N0-LS (paper Sec. 3.5, App. G).

All are special cases of FedNL's template:

  Newton (N):        C = I, alpha = 1, H_i^0 = 0          (exact Hessians)
  Newton-Star (NS):  C = 0, alpha = 0, H_i^0 = hess_i(x*) (oracle)
  Newton-Zero (N0):  C = 0, alpha = 0, H_i^0 = hess_i(x0)
  N0-LS:             N0 direction + backtracking line search
"""

from __future__ import annotations

from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from .linalg import project_psd, solve_newton_system


class SimpleState(NamedTuple):
    x: jax.Array
    h: jax.Array  # fixed or current (d, d) Hessian estimate


def newton_step(x, grad_fn, hess_fn):
    """Classical Newton on the averaged problem."""
    g = jnp.mean(grad_fn(x), axis=0)
    h = jnp.mean(hess_fn(x), axis=0)
    return x - solve_newton_system(h, g)


def newton_run(x0, grad_fn, hess_fn, num_rounds):
    def body(x, _):
        xn = newton_step(x, grad_fn, hess_fn)
        return xn, xn

    final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
    return final, jnp.concatenate([x0[None], xs], axis=0)


def fixed_hessian_run(x0, h_fixed, grad_fn, num_rounds, mu: float = 0.0):
    """NS (h_fixed = hess(x*)) and N0 (h_fixed = hess(x0)); eq. (9)/(55)."""
    h_eff = project_psd(h_fixed, mu) if mu > 0 else h_fixed

    def body(x, _):
        g = jnp.mean(grad_fn(x), axis=0)
        xn = x - solve_newton_system(h_eff, g)
        return xn, xn

    final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
    return final, jnp.concatenate([x0[None], xs], axis=0)


def backtracking(value_fn, x, d_dir, g, c: float = 0.5, gamma: float = 0.5,
                 max_steps: int = 30):
    """Smallest integer s >= 0 with
    f(x + gamma^s d) <= f(x) + c gamma^s <g, d>  (paper line 12, Alg 3).
    Returns the accepted stepsize gamma^s."""
    f0 = value_fn(x)
    slope = jnp.dot(g, d_dir)

    def cond(carry):
        s, t, done = carry
        return jnp.logical_and(~done, s < max_steps)

    def body(carry):
        s, t, _ = carry
        ok = value_fn(x + t * d_dir) <= f0 + c * t * slope
        t_next = jnp.where(ok, t, t * gamma)
        return s + 1, t_next, ok

    _, t, _ = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), jnp.ones_like(f0), jnp.zeros((), bool)))
    return t


def n0_ls_run(x0, h_fixed, value_fn, grad_fn, num_rounds, mu: float = 0.0,
              c: float = 0.5, gamma: float = 0.5):
    """Newton-Zero with backtracking line search (N0-LS)."""
    h_eff = project_psd(h_fixed, mu) if mu > 0 else h_fixed

    def body(x, _):
        g = jnp.mean(grad_fn(x), axis=0)
        d_dir = -solve_newton_system(h_eff, g)
        t = backtracking(value_fn, x, d_dir, g, c=c, gamma=gamma)
        xn = x + t * d_dir
        return xn, xn

    final, xs = jax.lax.scan(body, x0, None, length=num_rounds)
    return final, jnp.concatenate([x0[None], xs], axis=0)
