"""Reference Newton-type methods: N, NS, N0, N0-LS (paper Sec. 3.5, App. G).

All are special cases of FedNL's template:

  Newton (N):        C = I, alpha = 1, H_i^0 = 0          (exact Hessians)
  Newton-Star (NS):  C = 0, alpha = 0, H_i^0 = hess_i(x*) (oracle)
  Newton-Zero (N0):  C = 0, alpha = 0, H_i^0 = hess_i(x0)
  N0-LS:             N0 direction + backtracking line search

Each is a ``Method`` (engine protocol): init/step/bits_per_round, with
the round loop supplied by ``MethodBase``. The module-level ``*_run``
functions are kept as thin wrappers over the classes.
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..engine.method import MethodBase, Oracles, register
from .compressors import FLOAT_BITS
from .linalg import project_psd, solve_newton_system


class SimpleState(NamedTuple):
    x: jax.Array
    h: jax.Array  # fixed or current (d, d) Hessian estimate


class Newton(MethodBase):
    """Classical Newton on the averaged problem (uncompressed)."""

    silo_fields = ()

    def __init__(self, grad_fn, hess_fn):
        self.grad_fn = grad_fn
        self.hess_fn = hess_fn

    def init(self, x0, n: int = 0, seed: int = 0) -> SimpleState:
        # h is recomputed from x every step; don't pay a Hessian eval here
        d = x0.shape[0]
        return SimpleState(x=x0, h=jnp.zeros((d, d), x0.dtype))

    def step(self, state: SimpleState) -> SimpleState:
        g = jnp.mean(self.grad_fn(state.x), axis=0)
        h = jnp.mean(self.hess_fn(state.x), axis=0)
        return SimpleState(x=state.x - solve_newton_system(h, g), h=h)

    def bits_per_round(self, d: int) -> int:
        # gradient + full symmetric Hessian per device per round
        return d * FLOAT_BITS + d * (d + 1) // 2 * FLOAT_BITS


class FixedHessian(MethodBase):
    """NS (h_fixed = hess(x*)) and N0 (h_fixed = hess(x0)); eq. (9)/(55).

    When ``h_fixed`` is None the estimate is frozen at the mean local
    Hessian at x0 — Newton-Zero's initialization."""

    silo_fields = ()

    def __init__(self, grad_fn, h_fixed: Optional[jax.Array] = None,
                 hess_fn=None, mu: float = 0.0):
        assert h_fixed is not None or hess_fn is not None
        self.grad_fn = grad_fn
        self.h_fixed = h_fixed
        self.hess_fn = hess_fn
        self.mu = mu

    def _h_eff(self, x0):
        h = self.h_fixed
        if h is None:
            h = jnp.mean(self.hess_fn(x0), axis=0)
        return project_psd(h, self.mu) if self.mu > 0 else h

    def init(self, x0, n: int = 0, seed: int = 0) -> SimpleState:
        return SimpleState(x=x0, h=self._h_eff(x0))

    def step(self, state: SimpleState) -> SimpleState:
        g = jnp.mean(self.grad_fn(state.x), axis=0)
        return state._replace(x=state.x - solve_newton_system(state.h, g))

    def bits_per_round(self, d: int) -> int:
        return d * FLOAT_BITS  # gradient only — the Hessian never moves

    def init_bits(self, d: int) -> int:
        """The one-time cost of shipping the frozen Hessian estimate
        (hess(x0) for N0, hess(x*) for NS) — the paper's accounting."""
        return d * (d + 1) // 2 * FLOAT_BITS


def backtracking(value_fn, x, d_dir, g, c: float = 0.5, gamma: float = 0.5,
                 max_steps: int = 30):
    """Smallest integer s >= 0 with
    f(x + gamma^s d) <= f(x) + c gamma^s <g, d>  (paper line 12, Alg 3).
    Returns the accepted stepsize gamma^s."""
    f0 = value_fn(x)
    slope = jnp.dot(g, d_dir)

    def cond(carry):
        s, t, done = carry
        return jnp.logical_and(~done, s < max_steps)

    def body(carry):
        s, t, _ = carry
        ok = value_fn(x + t * d_dir) <= f0 + c * t * slope
        t_next = jnp.where(ok, t, t * gamma)
        return s + 1, t_next, ok

    _, t, _ = jax.lax.while_loop(cond, body, (jnp.zeros((), jnp.int32), jnp.ones_like(f0), jnp.zeros((), bool)))
    return t


class N0LS(FixedHessian):
    """Newton-Zero direction + backtracking line search (N0-LS)."""

    def __init__(self, value_fn, grad_fn, h_fixed: Optional[jax.Array] = None,
                 hess_fn=None, mu: float = 0.0, c: float = 0.5,
                 gamma: float = 0.5):
        super().__init__(grad_fn, h_fixed=h_fixed, hess_fn=hess_fn, mu=mu)
        self.value_fn = value_fn
        self.c = c
        self.gamma = gamma

    def step(self, state: SimpleState) -> SimpleState:
        g = jnp.mean(self.grad_fn(state.x), axis=0)
        d_dir = -solve_newton_system(state.h, g)
        t = backtracking(self.value_fn, state.x, d_dir, g, c=self.c,
                         gamma=self.gamma)
        return state._replace(x=state.x + t * d_dir)

    def bits_per_round(self, d: int) -> int:
        return FLOAT_BITS + d * FLOAT_BITS  # f_i probe + gradient


# -- legacy function drivers (wrappers over the Method classes) ----------------


def newton_step(x, grad_fn, hess_fn):
    """Classical Newton on the averaged problem."""
    g = jnp.mean(grad_fn(x), axis=0)
    h = jnp.mean(hess_fn(x), axis=0)
    return x - solve_newton_system(h, g)


def newton_run(x0, grad_fn, hess_fn, num_rounds):
    final, xs = Newton(grad_fn, hess_fn).run(x0, 0, num_rounds)
    return final.x, xs


def fixed_hessian_run(x0, h_fixed, grad_fn, num_rounds, mu: float = 0.0):
    """NS (h_fixed = hess(x*)) and N0 (h_fixed = hess(x0)); eq. (9)/(55)."""
    final, xs = FixedHessian(grad_fn, h_fixed=h_fixed, mu=mu).run(
        x0, 0, num_rounds)
    return final.x, xs


def n0_ls_run(x0, h_fixed, value_fn, grad_fn, num_rounds, mu: float = 0.0,
              c: float = 0.5, gamma: float = 0.5):
    """Newton-Zero with backtracking line search (N0-LS)."""
    final, xs = N0LS(value_fn, grad_fn, h_fixed=h_fixed, mu=mu, c=c,
                     gamma=gamma).run(x0, 0, num_rounds)
    return final.x, xs


@register("newton")
def _make_newton(oracles: Oracles, compressor=None, **params):
    return Newton(oracles.grad, oracles.hess)


@register("n0")
def _make_n0(oracles: Oracles, compressor=None, **params):
    return FixedHessian(oracles.grad, hess_fn=oracles.hess, **params)


@register("ns")
def _make_ns(oracles: Oracles, compressor=None, *, h_fixed, **params):
    # NS needs the oracle Hessian at x*; pass it as h_fixed.
    return FixedHessian(oracles.grad, h_fixed=h_fixed, **params)


@register("n0-ls")
def _make_n0_ls(oracles: Oracles, compressor=None, **params):
    return N0LS(oracles.value, oracles.grad, hess_fn=oracles.hess, **params)
