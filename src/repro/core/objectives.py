"""Problem oracles for the FedNL experiments.

The paper's empirical problem (eq. (10)):

    min_x (1/n) sum_i f_i(x) + (lambda/2) ||x||^2,
    f_i(x) = (1/m) sum_j log(1 + exp(-b_ij a_ij^T x))

We expose per-silo oracles on stacked data tensors of shape
(n_silos, m, d) / (n_silos, m), each vmap/shard_map friendly:

    value_i, grad_i, hess_i  — per silo (take (m,d),(m,) slabs)
    batch_*                  — vmapped over the silo axis
    global_*                 — average over silos

The regularizer is split evenly into every f_i so that
f = (1/n) sum f_i matches eq. (10) exactly.

Also: quadratic oracles (for NS/N0 sanity) and GLM scaffolding used by
the NL1 baseline, which needs phi''_ij per data point (eq. (2)).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class LogRegData(NamedTuple):
    a: jax.Array  # (n, m, d) features
    b: jax.Array  # (n, m)    labels in {-1, +1}
    lam: float    # l2 regularization


# -- numerically stable pieces ------------------------------------------------


def _log1pexp(t: jax.Array) -> jax.Array:
    """log(1 + exp(t)) without overflow."""
    return jnp.logaddexp(0.0, t)


def _sigmoid(t: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(t)


# -- per-silo oracles ---------------------------------------------------------


def silo_value(x: jax.Array, a: jax.Array, b: jax.Array, lam: float) -> jax.Array:
    margins = -b * (a @ x)                     # (m,)
    return jnp.mean(_log1pexp(margins)) + 0.5 * lam * jnp.dot(x, x)


def silo_grad(x: jax.Array, a: jax.Array, b: jax.Array, lam: float) -> jax.Array:
    margins = -b * (a @ x)
    coef = _sigmoid(margins) * (-b)            # d/dz of log1pexp(-b z)
    return a.T @ coef / a.shape[0] + lam * x


def silo_hess(x: jax.Array, a: jax.Array, b: jax.Array, lam: float) -> jax.Array:
    margins = -b * (a @ x)
    s = _sigmoid(margins)
    w = s * (1.0 - s)                          # (m,) phi'' weights; b^2 = 1
    d = x.shape[0]
    return (a.T * w) @ a / a.shape[0] + lam * jnp.eye(d, dtype=x.dtype)


def silo_phi2(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """phi''_ij(a_ij^T x) for the GLM structure (NL1 baseline)."""
    margins = -b * (a @ x)
    s = _sigmoid(margins)
    return s * (1.0 - s)


# -- stacked (all-silo) oracles ----------------------------------------------


def batch_value(x: jax.Array, data: LogRegData) -> jax.Array:
    return jax.vmap(lambda a, b: silo_value(x, a, b, data.lam))(data.a, data.b)


def batch_grad(x: jax.Array, data: LogRegData) -> jax.Array:
    return jax.vmap(lambda a, b: silo_grad(x, a, b, data.lam))(data.a, data.b)


def batch_hess(x: jax.Array, data: LogRegData) -> jax.Array:
    return jax.vmap(lambda a, b: silo_hess(x, a, b, data.lam))(data.a, data.b)


def global_value(x: jax.Array, data: LogRegData) -> jax.Array:
    return jnp.mean(batch_value(x, data))


def global_grad(x: jax.Array, data: LogRegData) -> jax.Array:
    return jnp.mean(batch_grad(x, data), axis=0)


def global_hess(x: jax.Array, data: LogRegData) -> jax.Array:
    return jnp.mean(batch_hess(x, data), axis=0)


# -- constants of Assumption 3.1 ----------------------------------------------


def lipschitz_constants(data: LogRegData) -> dict:
    """Upper bounds on (mu, L, L_*, L_F, L_inf) for eq. (10).

    For logistic loss: |phi'''| <= 1/(6 sqrt(3)) <= 0.1; a crude and safe
    bound uses max_j ||a_ij||^3 / (10) per silo for the Hessian Lipschitz
    constants (spectral <= Frobenius), and L = max eig of (1/4m) A^T A + lam.
    mu >= lam always (each f_i is lam-strongly convex).
    """
    a = data.a
    norms = jnp.linalg.norm(a, axis=-1)                    # (n, m)
    c3 = 0.09623  # max |phi'''| = 1/(6 sqrt 3)
    l_star = float(jnp.max(jnp.mean(norms**3, axis=1)) * c3)
    l_f = l_star  # Frobenius-Lipschitz bound via the same rank-1 structure
    l_inf = float(jnp.max(jnp.mean(norms * jnp.max(jnp.abs(a), axis=-1) ** 2, axis=1)) * c3)
    smooth = float(jnp.max(jnp.mean(norms**2, axis=1)) / 4.0 + data.lam)
    return dict(mu=data.lam, L=smooth, L_star=l_star, L_F=l_f, L_inf=l_inf)


# -- quadratic oracles (for NS / N0 / unit tests) ------------------------------


class QuadData(NamedTuple):
    q: jax.Array   # (n, d, d) per-silo PSD matrices
    c: jax.Array   # (n, d)    per-silo linear terms


def quad_value(x: jax.Array, data: QuadData) -> jax.Array:
    vals = jax.vmap(lambda q, c: 0.5 * x @ q @ x - c @ x)(data.q, data.c)
    return jnp.mean(vals)


def quad_grad(x: jax.Array, data: QuadData) -> jax.Array:
    return jnp.mean(jax.vmap(lambda q, c: q @ x - c)(data.q, data.c), axis=0)


def quad_hess_batch(x: jax.Array, data: QuadData) -> jax.Array:
    return data.q
