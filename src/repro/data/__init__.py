from .synthetic import make_synthetic, make_iid, make_libsvm_like
from .libsvm import parse_libsvm, partition_across_silos
from .tokens import TokenPipeline
