from .libsvm import parse_libsvm, partition_across_silos
from .synthetic import make_iid, make_libsvm_like, make_synthetic
from .tokens import TokenPipeline
