"""LibSVM text-format parsing + cross-silo partitioning.

The paper evaluates on a1a/a9a/w7a/w8a/phishing from LibSVM. This module
parses the standard ``label idx:val ...`` text format (so real files drop
in when present) and partitions rows evenly across n silos as the paper's
Table 3 does. In this offline container the benchmarks fall back to
``data.synthetic.make_libsvm_like`` with identical shapes.
"""

from __future__ import annotations

import numpy as np

from repro.core.objectives import LogRegData


def parse_libsvm(text: str, d: int | None = None) -> tuple[np.ndarray, np.ndarray]:
    """Parse LibSVM text into dense (N, d) features and (N,) +-1 labels."""
    rows = []
    labels = []
    max_idx = 0
    for line in text.strip().splitlines():
        parts = line.split()
        if not parts:
            continue
        y = float(parts[0])
        feats = {}
        for tok in parts[1:]:
            if ":" not in tok:
                continue
            i, v = tok.split(":")
            i = int(i)
            feats[i] = float(v)
            max_idx = max(max_idx, i)
        labels.append(-1.0 if y <= 0 else 1.0)
        rows.append(feats)
    dim = d if d is not None else max_idx
    a = np.zeros((len(rows), dim), np.float32)
    for r, feats in enumerate(rows):
        for i, v in feats.items():
            if i <= dim:
                a[r, i - 1] = v
    return a, np.asarray(labels, np.float32)


def partition_across_silos(a: np.ndarray, b: np.ndarray, n: int,
                           lam: float = 1e-3) -> LogRegData:
    """Even, contiguous partition into n silos of m = floor(N/n) points
    (rows beyond n*m are dropped, matching Table 3's nm counts)."""
    m = a.shape[0] // n
    a_s = a[: n * m].reshape(n, m, a.shape[1])
    b_s = b[: n * m].reshape(n, m)
    import jax.numpy as jnp

    return LogRegData(a=jnp.asarray(a_s), b=jnp.asarray(b_s), lam=lam)
