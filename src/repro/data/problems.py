"""Canonical benchmark problems: the experimental setup of eq. (10) on
LibSVM-shaped stand-ins (Table 3 sizes) or the Sec. A.14 synthetic
generator, packaged as the oracle dict the engine and benchmark harness
consume. Single source of truth — ``benchmarks/common.py`` and
``repro.launch.sweep`` both delegate here.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..core.newton import newton_run
from ..core.objectives import batch_grad, batch_hess, global_value, lipschitz_constants
from .synthetic import make_libsvm_like, make_synthetic


def make_problem(name: str = "a1a", lam: float = 1e-3, seed: int = 0) -> dict:
    """Returns dict with oracles, x*, constants. 'a1a' etc. use Table 3
    shapes; 'synthetic:ALPHA:BETA' uses the Sec. A.14 generator."""
    key = jax.random.PRNGKey(seed)
    if name.startswith("synthetic"):
        _, alpha, beta = name.split(":")
        data = make_synthetic(key, float(alpha), float(beta), n=30, m=200,
                              d=100, lam=lam)
    else:
        data = make_libsvm_like(key, name, lam=lam)
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    val_fn = lambda x: global_value(x, data)
    d = data.a.shape[-1]
    xstar, _ = newton_run(jnp.zeros(d), grad_fn, hess_fn, 25)
    return dict(
        data=data, grad=grad_fn, hess=hess_fn, val=val_fn, xstar=xstar,
        fstar=float(val_fn(xstar)), d=d, n=data.a.shape[0],
        consts=lipschitz_constants(data),
    )
