"""Synthetic federated logistic-regression data (paper Sec. A.14).

``make_synthetic(alpha, beta)`` follows the non-IID generator of
Li et al. 2018 as the paper describes:

  per silo i: B_i ~ N(0, beta); v_i entries ~ N(B_i, 1);
  features a_ij ~ N(v_i, Sigma) with Sigma_jj = j^{-1.2};
  u_i ~ N(0, alpha); c_i ~ N(u_i, 1); w_i entries ~ N(u_i, 1);
  p_ij = sigmoid(w_i^T a_ij + c_i); b_ij = -1 w.p. p_ij else +1.

``make_iid`` samples one (w, c) pair shared by all silos.
``make_libsvm_like`` mimics the LibSVM datasets' shapes used in Table 3
(a1a, a9a, w7a, w8a, phishing) with sparse-ish binary features, so every
paper figure has a stand-in when the real files are absent (offline env).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.objectives import LogRegData

# Table 3 of the paper
LIBSVM_SHAPES = {
    "a1a": dict(n=16, m=100, d=123),
    "a9a": dict(n=80, m=407, d=123),
    "w7a": dict(n=50, m=492, d=300),
    "w8a": dict(n=142, m=350, d=300),
    "phishing": dict(n=100, m=110, d=68),
}


def _labels_from_logits(key, logits):
    p_neg = jax.nn.sigmoid(logits)
    neg = jax.random.bernoulli(key, p_neg)
    return jnp.where(neg, -1.0, 1.0)


def make_synthetic(key, alpha: float, beta: float, n: int = 30, m: int = 200,
                   d: int = 100, lam: float = 1e-3) -> LogRegData:
    ks = jax.random.split(key, 7)
    sigma_diag = (jnp.arange(1, d + 1, dtype=jnp.float32)) ** -1.2

    b_i = jax.random.normal(ks[0], (n,)) * jnp.sqrt(beta)
    v = b_i[:, None] + jax.random.normal(ks[1], (n, d))
    a = v[:, None, :] + jax.random.normal(ks[2], (n, m, d)) * jnp.sqrt(sigma_diag)

    u_i = jax.random.normal(ks[3], (n,)) * jnp.sqrt(alpha)
    c_i = u_i + jax.random.normal(ks[4], (n,))
    w = u_i[:, None] + jax.random.normal(ks[5], (n, d))

    logits = jnp.einsum("nmd,nd->nm", a, w) + c_i[:, None]
    b = _labels_from_logits(ks[6], logits)
    return LogRegData(a=a, b=b, lam=lam)


def make_iid(key, beta: float = 1.0, n: int = 30, m: int = 200, d: int = 100,
             lam: float = 1e-3) -> LogRegData:
    ks = jax.random.split(key, 6)
    sigma_diag = (jnp.arange(1, d + 1, dtype=jnp.float32)) ** -1.2

    b_i = jax.random.normal(ks[0], (n,)) * jnp.sqrt(beta)
    v = jnp.tile(b_i[:, None], (1, d))
    a = v[:, None, :] + jax.random.normal(ks[1], (n, m, d)) * jnp.sqrt(sigma_diag)

    w = jax.random.normal(ks[2], (d,))
    c = jax.random.normal(ks[3], ())
    logits = jnp.einsum("nmd,d->nm", a, w) + c
    b = _labels_from_logits(ks[4], logits)
    return LogRegData(a=a, b=b, lam=lam)


def make_libsvm_like(key, name: str, lam: float = 1e-3,
                     scale: float = 1.0) -> LogRegData:
    """Stand-in with the dataset's (n, m, d) from Table 3: binary-ish
    sparse features (density ~0.15 like a9a) + a planted linear teacher."""
    spec = LIBSVM_SHAPES[name]
    n, m, d = spec["n"], spec["m"], spec["d"]
    ks = jax.random.split(key, 4)
    density = 0.15
    mask = jax.random.bernoulli(ks[0], density, (n, m, d))
    a = mask.astype(jnp.float32) * scale
    w = jax.random.normal(ks[1], (d,)) / jnp.sqrt(d * density)
    logits = jnp.einsum("nmd,d->nm", a, w)
    b = _labels_from_logits(ks[2], logits)
    return LogRegData(a=a, b=b, lam=lam)
