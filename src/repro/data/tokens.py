"""Token data pipeline for the LM training/serving substrate.

Synthetic-but-structured corpus: a mixture of Zipfian unigram draws and
short copied motifs so the loss has learnable signal (pure uniform noise
would make optimizer comparisons meaningless). Deterministic per (seed,
step) — no filesystem dependency — and shardable: ``global_batch`` is laid
out so the leading axis shards over ("pod", "data").

For multimodal archs the pipeline also synthesizes the stubbed frontend
embeddings (audio frames / vision patches) via ``extra_inputs``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenPipeline:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 16
    num_motifs: int = 256

    def _motifs(self) -> jax.Array:
        key = jax.random.PRNGKey(self.seed)
        return jax.random.randint(
            key, (self.num_motifs, self.motif_len), 0, self.vocab_size)

    def batch(self, step: int) -> dict:
        """Returns {'tokens': (B, T) int32, 'targets': (B, T) int32}."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        k1, k2, k3 = jax.random.split(key, 3)
        b, t = self.global_batch, self.seq_len

        # Zipfian unigrams via inverse-CDF on a power law.
        u = jax.random.uniform(k1, (b, t), minval=1e-6, maxval=1.0)
        ranks = jnp.floor(jnp.exp(u * jnp.log(float(self.vocab_size)))) - 1.0
        tokens = ranks.astype(jnp.int32) % self.vocab_size

        # Paste motifs at random offsets (learnable bigram structure).
        motifs = self._motifs()
        which = jax.random.randint(k2, (b,), 0, self.num_motifs)
        offs = jax.random.randint(k3, (b,), 0, max(1, t - self.motif_len))

        def paste(row, motif, off):
            idx = off + jnp.arange(self.motif_len)
            return row.at[idx].set(motif)

        tokens = jax.vmap(paste)(tokens, motifs[which], offs)
        targets = jnp.roll(tokens, -1, axis=-1)
        return {"tokens": tokens, "targets": targets}
