"""Unified experiment engine for the FedNL family.

One protocol (``Method``), one registry (construct any method by string
key), one driver (vmap-over-seeds + scan-over-rounds), one sweep runner
(``ExperimentSpec`` grids -> stacked histories + tidy records). See
``method.py`` for the protocol contract and ``sweep.py`` for execution.
"""

from ..core.compressors import (
    available_compressors,
    make_compressor,
    payload_bits,
    register_compressor,
    scale_payload,
)
from ..wire import LinkModel, WireReport, link_model, round_seconds, wire_cost
from .method import (
    MethodBase,
    Oracles,
    available_methods,
    make_method,
    register,
    scan_rounds,
)
from .records import (
    bits_curve,
    bits_to_accuracy,
    entropy_bits_curve,
    init_bits,
    measured_bits_curve,
    measured_bits_per_round,
    rounds_to_accuracy,
    seconds_curve,
    seconds_per_round,
    summary_records,
    uplink_bits_per_round,
)
from .sweep import (
    CellResult,
    ExperimentSpec,
    Sweep,
    SweepResult,
    build_compressor,
    run_cell,
    run_sweep,
)

#: ``CohortSpec`` re-exported lazily: ``core.cohort`` imports this
#: package's ``method`` submodule (to register "fednl-cohort"), so a
#: top-level ``from ..core.cohort import ...`` here would be a cycle.
#: Module __getattr__ defers the import until first access.


def __getattr__(name):
    if name == "CohortSpec":
        from ..core.cohort import CohortSpec

        return CohortSpec
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
