"""The ``Method`` protocol and registry — the contract every optimizer in
the FedNL family (and the Newton reference methods) implements so one
engine can drive all of them.

A *method* is a stateless-config object with three hooks:

  init(x0, n, *, seed=0, **kw) -> State   # pytree (NamedTuple) of arrays
  step(State) -> State                    # one communication round, jittable
  bits_per_round(d) -> int | (int, int)   # analytic uplink (and downlink)

plus two class attributes consumed by the shared driver:

  traj_field: str   # which State field is the monitored iterate
                    # ("x" for most methods, "z" for FedNL-BC)
  silo_fields: tuple[str, ...]  # State fields with a leading silo axis
                    # (used by the shard_map execution path)

``MethodBase`` supplies the single ``run`` loop (lax.scan over rounds)
that used to be copy-pasted into every algorithm module, and
``scan_rounds`` is the same driver in function form for the sweep
runner, where it sits under an extra ``vmap`` over seeds.

The registry maps string keys ("fednl", "fednl-pp", ...) to factories
``factory(oracles, compressor=None, **params) -> Method`` so sweeps and
CLIs can construct any method declaratively. Factories self-register in
the module that defines the method class.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Optional, Protocol, runtime_checkable

import jax
import jax.numpy as jnp


class Oracles(NamedTuple):
    """Problem oracles in the paper's federated form.

    value: x -> ()        global objective f(x) (may be None for methods
                          that never evaluate f, e.g. plain FedNL)
    grad:  x -> (n, d)    stacked per-silo gradients
    hess:  x -> (n, d, d) stacked per-silo Hessians
    """

    value: Optional[Callable[[jax.Array], jax.Array]]
    grad: Callable[[jax.Array], jax.Array]
    hess: Callable[[jax.Array], jax.Array]


@runtime_checkable
class Method(Protocol):
    traj_field: str

    def init(self, x0: jax.Array, n: int, *, seed=0, **kw): ...

    def step(self, state): ...

    def bits_per_round(self, d: int): ...


def scan_rounds(method, state, num_rounds: int):
    """Shared round loop: ``lax.scan`` of ``method.step``, recording the
    method's monitored iterate each round. Returns (final_state, xs)
    with xs of shape (num_rounds, d) — the caller prepends x0."""

    def body(s, _):
        ns = method.step(s)
        return ns, getattr(ns, method.traj_field)

    return jax.lax.scan(body, state, None, length=num_rounds)


class MethodBase:
    """Mixin providing the one true ``run`` driver plus the shared
    payload wire helpers.

    Subclasses implement init/step/bits_per_round; ``run`` is the scan
    loop every algorithm module used to duplicate. The uplink is split
    the way the deployment is: ``_uplink_payloads`` (device side:
    compress), ``_local_hessians`` (device side: each silo's OWN dense
    S_i for its H_i update), ``_server_aggregate`` (server side: ONE
    dense (d, d) mean straight from payload space — no silo's dense
    matrix ever reaches the server, and no (n, d, d) stack is formed
    there). ``measured_bits_per_round`` is the measured wire accounting
    every compressed method shares.
    """

    traj_field: str = "x"
    silo_fields: tuple = ("h_local",)

    def _uplink_payloads(self, diff, silo_keys):
        """Device side: each silo compresses its own (d, d) Hessian
        diff into the wire payload it uplinks (vmapped over the silo
        axis; payload shapes are static)."""
        return jax.vmap(self.comp.compress)(diff, silo_keys)

    def _uplink_diff_payloads(self, h_new, h_old, silo_keys):
        """Device side, fused: payloads of D_i = h_new_i - h_old_i plus
        l_i = ||D_i||_F, both from one pass. Compressors exposing
        ``fused_diff_payloads`` (the block-sparse family) diff, select,
        and emit tile-wise inside a single kernel — the dense (n, d, d)
        difference never round-trips through HBM on the Pallas path;
        everyone else falls back to compress(h_new - h_old). Callers
        that don't need the norms leave them dead (XLA DCE removes the
        reduction)."""
        fused = getattr(self.comp, "fused_diff_payloads", None)
        if fused is not None:
            return fused(h_new, h_old)
        from ..core.linalg import frob_norm

        diff = h_new - h_old
        return (jax.vmap(self.comp.compress)(diff, silo_keys),
                jax.vmap(frob_norm)(diff))

    def _local_hessians(self, payloads, shape):
        """Device side: each silo reconstructs its OWN dense S_i from
        the payload it just built — the H_i^{k+1} = H_i^k + alpha S_i^k
        update happens on-device, per silo, never aggregated."""
        return jax.vmap(lambda p: self.comp.decompress(p, shape))(payloads)

    def _server_aggregate(self, payloads, shape, weights=None):
        """Server side: S^k = mean_i S_i^k computed in payload space
        (``Compressor.aggregate`` — scatter-add / stacked factors /
        direct mean, one dense accumulator total). ``weights`` rescales
        per-silo contributions (partial-participation masks with 0/1,
        the cohort layer's staleness weights) and is applied INSIDE
        ``aggregate`` — the one weighting point for every wire format.
        Under shard_map (``axis_name`` set) the cross-silo reduction
        happens HERE, on the dense accumulator: one pmean of (d, d)."""
        s = self.comp.aggregate(payloads, shape, weights=weights)
        axis = getattr(self, "axis_name", None)
        if axis is not None:
            s = jax.lax.pmean(s, axis)
        return s

    def measured_bits_per_round(self, d: int, index_coding: str = "raw"):
        """MEASURED per-round wire bits: the compressor's actual payload
        structure (via jax.eval_shape) plus the (d + 1) uncompressed
        floats every single-uplink FedNL variant ships (gradient-sized
        vector + one scalar), at the ambient float width — matches the
        analytic ``bits_per_round`` layout of FedNL/PP/CR/LS/Stochastic
        under x64. ``index_coding="entropy"`` charges the sparsifier
        index streams their entropy-coded estimate (log2 C(d^2, k))
        instead of k raw 32-bit ints. Methods with a different wire
        layout (FedNL-BC, FedNL-PPBC) override. Payload-free methods
        (Newton references) return the analytic number: their wire IS
        dense FLOAT_BITS floats, so the claim equals the wire count by
        construction."""
        comp = getattr(self, "comp", None)
        if comp is None:
            return self.bits_per_round(d)
        from ..core.compressors import canonical_float_bits
        from ..wire.report import wire_cost

        rep = wire_cost(comp, (d, d), encoded=False)
        s_bits = rep.entropy_bits if index_coding == "entropy" else rep.raw_bits
        return s_bits + (d + 1) * canonical_float_bits()

    def run(self, x0, n, num_rounds, *args, seed: int = 0, **init_kw):
        """Run ``num_rounds`` communication rounds from ``x0``.

        Returns (final_state, (num_rounds+1, d) iterate history with x0
        prepended). Extra positional/keyword args (e.g. ``h0``) are
        forwarded to ``init``.
        """
        state = self.init(x0, n, *args, seed=seed, **init_kw)
        final, xs = scan_rounds(self, state, num_rounds)
        return final, jnp.concatenate([jnp.asarray(x0)[None], xs], axis=0)


# -- registry -----------------------------------------------------------------

_REGISTRY: dict[str, Callable[..., Any]] = {}


def register(name: str):
    """Decorator: register ``factory(oracles, compressor=None, **params)``
    under ``name``. Re-registration overwrites (last wins) so notebooks
    can hot-patch methods."""

    def deco(factory):
        _REGISTRY[name] = factory
        return factory

    return deco


def _ensure_registered() -> None:
    # Factories live next to their method classes in repro.core; import
    # lazily to avoid a package-init cycle (core modules import this
    # module for MethodBase). Unconditional: a user registering their own
    # method first must not hide the built-ins (sys.modules makes this
    # free after the first call).
    from .. import core  # noqa: F401


def available_methods() -> list[str]:
    _ensure_registered()
    return sorted(_REGISTRY)


def registered_methods() -> dict[str, Callable[..., Any]]:
    """Snapshot of the method registry (name -> factory) — the
    introspection hook the static-analysis sweep (``repro.analysis``)
    enumerates so every registered method gets traced and checked."""
    _ensure_registered()
    return dict(_REGISTRY)


def make_method(name: str, oracles: Oracles, compressor=None, **params):
    """Construct a registered method by string key.

    ``params`` are forwarded to the factory (e.g. alpha, option, mu,
    tau, p, eta, l_star, model_compressor)."""
    _ensure_registered()
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown method {name!r}; available: {available_methods()}"
        ) from None
    for k, v in params.items():
        # declarative compressor params: ("topk", 16) -> TopK(k=16),
        # resolved through the compressor registry in core.compressors
        if k.endswith("compressor") and isinstance(v, tuple):
            from ..core.compressors import make_compressor

            params[k] = make_compressor(*v)
    return factory(oracles, compressor, **params)
