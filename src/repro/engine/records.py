"""Tidy-record emission and communication accounting for sweep results.

The paper's x-axis is cumulative communicated bits per node; every cell
of a sweep carries an analytic bits curve (``bits_curve``) AND a
measured one (``measured_bits_curve`` — per-round wire sizes derived
from the compressor payload structure via ``measured_bits_per_round``)
next to its gap curve, so figure code reduces to "plot records" and a
divergence between claim and wire is visible per row. A fourth column,
``seconds_per_round``, prices the measured wire through the traffic
model (``repro.wire.traffic`` — link presets, straggler-dominated
synchronous rounds), turning the bits x-axis into simulated wall-clock.
``records`` flattens a sweep into a list of plain dicts (one row per
(cell, seed, round)) — trivially convertible to CSV or a dataframe.
"""

from __future__ import annotations

from typing import Optional

import numpy as np


def uplink_bits_per_round(method, d: int) -> float:
    """Total per-round communication charged on the paper's x-axis.

    Methods with bidirectional compression (FedNL-BC and friends) return
    an (uplink, downlink) tuple from ``bits_per_round``; the figures
    charge the sum."""
    b = method.bits_per_round(d)
    if isinstance(b, tuple):
        return float(sum(b))
    return float(b)


def measured_bits_per_round(method, d: int,
                            index_coding: str = "raw") -> float:
    """Total per-round communication as MEASURED from the method's
    payload structure (``method.measured_bits_per_round``, built on
    ``jax.eval_shape`` over the compressor payloads). For methods
    without payload accounting (uncompressed baselines/references) the
    analytic number is returned: their wire is dense FLOAT_BITS floats,
    so claim == wire by construction, not by measurement — for
    compressed methods the two columns are independent and a divergence
    is a real claim-vs-wire gap. ``index_coding="entropy"`` charges the
    sparsifier index streams their entropy-coded information cost
    (log2 C(d^2, k)) instead of raw 32-bit ints — the third accounting
    column of sweep records."""
    fn = getattr(method, "measured_bits_per_round", None)
    if fn is None:
        return uplink_bits_per_round(method, d)
    # custom methods may predate the index_coding kwarg — dispatch on
    # the signature rather than try/except, which would swallow a
    # genuine TypeError raised inside a conforming override
    import inspect

    if "index_coding" in inspect.signature(fn).parameters:
        b = fn(d, index_coding=index_coding)
    else:
        b = fn(d)
    if isinstance(b, tuple):
        return float(sum(b))
    return float(b)


def init_bits(method, d: int) -> float:
    """One-time setup cost (e.g. shipping H_i^0); 0 when undefined."""
    fn = getattr(method, "init_bits", None)
    return float(fn(d)) if fn is not None else 0.0


def bits_curve(method, d: int, num_rounds: int) -> np.ndarray:
    """(num_rounds+1,) cumulative bits per node, paper accounting."""
    per = uplink_bits_per_round(method, d)
    return init_bits(method, d) + per * np.arange(num_rounds + 1)


def measured_bits_curve(method, d: int, num_rounds: int) -> np.ndarray:
    """(num_rounds+1,) cumulative MEASURED bits per node: per-round wire
    sizes from the payload structure; the one-time init cost stays the
    analytic dense-symmetric ship (there is no payload for it)."""
    per = measured_bits_per_round(method, d)
    return init_bits(method, d) + per * np.arange(num_rounds + 1)


def entropy_bits_curve(method, d: int, num_rounds: int) -> np.ndarray:
    """(num_rounds+1,) cumulative measured bits with the sparsifier
    index streams entropy-coded (accounting estimate only — no codec):
    the per-round wire size a k-subset-of-d^2 index coder would
    approach, <= the raw measured curve by construction."""
    per = measured_bits_per_round(method, d, index_coding="entropy")
    return init_bits(method, d) + per * np.arange(num_rounds + 1)


def seconds_per_round(method, d: int, n: int, link="wan",
                      seed: int = 0) -> float:
    """Simulated wall-clock seconds for ONE synchronous round: the
    method's MEASURED per-round wire bits priced through the traffic
    model (``repro.wire.traffic.round_seconds``) for an ``n``-silo
    cohort on ``link`` (a preset name or ``LinkModel``). The server
    waits for the straggler, so heterogeneous links make ``n`` matter."""
    from ..wire.traffic import round_seconds

    per = measured_bits_per_round(method, d)
    return round_seconds(per, link, n=n, seed=seed)


def seconds_curve(method, d: int, n: int, num_rounds: int, link="wan",
                  seed: int = 0) -> np.ndarray:
    """(num_rounds+1,) cumulative simulated seconds — the time-domain
    twin of ``measured_bits_curve`` (same per-round wire size, priced
    by the traffic model; the one-time init ship is charged too)."""
    from ..wire import traffic

    return traffic.seconds_curve(
        measured_bits_per_round(method, d), link, n, num_rounds,
        init_bits=init_bits(method, d), seed=seed)


def bits_to_accuracy(gap_curve, bits: np.ndarray, target: float) -> float:
    """First cumulative-bits value at which gap <= target (inf if never)."""
    gap_curve = np.asarray(gap_curve)
    idx = np.nonzero(gap_curve <= target)[0]
    if len(idx) == 0:
        return float("inf")
    return float(bits[idx[0]])


def rounds_to_accuracy(gap_curve, target: float) -> int:
    idx = np.nonzero(np.asarray(gap_curve) <= target)[0]
    return int(idx[0]) if len(idx) else -1


def cell_records(cell) -> list[dict]:
    """One tidy row per (seed, round) for a finished ``CellResult``.
    Three accounting columns side by side: ``bits`` is the paper's
    analytic curve, ``bits_measured`` the wire sizes measured from the
    payload structure (raw 32-bit index streams), ``bits_entropy`` the
    same wire with entropy-coded index streams."""
    spec = cell.spec
    measured = getattr(cell, "bits_measured", None)
    if measured is None:
        measured = cell.bits
    entropy = getattr(cell, "bits_entropy", None)
    if entropy is None:
        entropy = measured
    spr = getattr(cell, "seconds_per_round", None)
    spr = float("nan") if spr is None else float(spr)
    rows = []
    for si, seed in enumerate(spec.seeds):
        for k in range(cell.gaps.shape[1]):
            rows.append(
                dict(
                    name=spec.label,
                    method=spec.method,
                    compressor=spec.compressor or "",
                    level=spec.level if spec.level is not None else "",
                    seed=seed,
                    round=k,
                    bits=float(cell.bits[k]),
                    bits_measured=float(measured[k]),
                    bits_entropy=float(entropy[k]),
                    gap=float(cell.gaps[si, k]),
                    us_per_round=cell.us_per_round,
                    seconds_per_round=spr,
                )
            )
    return rows


def summary_records(cells, target: Optional[float] = None) -> list[dict]:
    """One row per cell: wall-clock and (optionally) bits/rounds to
    ``target`` accuracy for the first seed (the paper's single-run
    figures) plus the across-seed worst case."""
    rows = []
    for cell in cells:
        measured = getattr(cell, "bits_measured", None)
        if measured is None:
            measured = cell.bits
        entropy = getattr(cell, "bits_entropy", None)
        if entropy is None:
            entropy = measured
        row = dict(
            name=cell.spec.label,
            method=cell.spec.method,
            compressor=cell.spec.compressor or "",
            level=cell.spec.level if cell.spec.level is not None else "",
            num_seeds=len(cell.spec.seeds),
            bits_per_round=float(cell.bits[1] - cell.bits[0])
            if len(cell.bits) > 1 else 0.0,
            bits_per_round_measured=float(measured[1] - measured[0])
            if len(measured) > 1 else 0.0,
            bits_per_round_entropy=float(entropy[1] - entropy[0])
            if len(entropy) > 1 else 0.0,
            us_per_round=cell.us_per_round,
            seconds_per_round=float("nan")
            if getattr(cell, "seconds_per_round", None) is None
            else float(cell.seconds_per_round),
        )
        if target is not None:
            row["bits_to_target"] = bits_to_accuracy(
                cell.gaps[0], cell.bits, target)
            row["rounds_to_target"] = rounds_to_accuracy(cell.gaps[0], target)
            row["bits_to_target_worst_seed"] = max(
                bits_to_accuracy(g, cell.bits, target) for g in cell.gaps)
        rows.append(row)
    return rows
