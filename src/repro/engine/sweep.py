"""Declarative experiment sweeps for the FedNL family.

The paper's figures are grids — method x compressor x level x seed — and
the seed-era harness executed every cell as its own Python loop. Here a
grid is a list of ``ExperimentSpec`` cells and the ``Sweep`` runner
executes each cell as ONE jitted program: ``jax.vmap`` stacks the
homogeneous seed axis and ``lax.scan`` runs the rounds, so an s-seed
cell costs roughly one single-run wall-clock instead of s. Compressor
levels are static to XLA (top-k sizes, SVD ranks), so distinct levels
compile per cell-shape; hold on to ``batched_runner``'s callable to
amortize the trace across repeated executions of the same cell.

Execution paths:

* default — vmap-over-seeds + scan-over-rounds, single process;
* ``mesh=`` — the shard_map path of ``core/federated.py``: silo data and
  Hessian state sharded over the mesh's "data" axis, one pod runs the
  cell (currently the plain-FedNL cells; other cells fall back to vmap).

Results come back as ``CellResult`` (stacked iterate/gap histories, the
analytic AND measured cumulative-bits curves, per-cell ``us_per_round``,
and traffic-model ``seconds_per_round`` — measured wire bits priced on
the sweep's ``link`` preset) and tidy row dicts via
``SweepResult.records()`` — figure code becomes spec + plot, with
``bits``/``bits_measured``/``seconds_per_round`` side by side per row.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import records as rec
from .method import Oracles, make_method, scan_rounds


# -- compressor construction by (family, level) --------------------------------


def build_compressor(family: str, level=None):
    """String-keyed compressor factory — now a thin alias for the
    self-registering registry in ``core.compressors``
    (``make_compressor``); kept so engine callers and old specs keep
    working."""
    from ..core.compressors import make_compressor

    return make_compressor(family, level)


# -- specs ---------------------------------------------------------------------


@dataclass(frozen=True)
class ExperimentSpec:
    """One cell of a sweep grid.

    method:     registry key ("fednl", "fednl-pp", "fednl-bc", ...)
    compressor: compressor family for ``build_compressor`` (None for
                methods that take no compressor, e.g. "newton")
    level:      the family's level knob (rank / k / s)
    params:     extra method kwargs (alpha, option, mu, tau, p, eta,
                l_star, model_compressor=("topk", k), ...)
    seeds:      PRNG seeds — stacked into one vmapped program
    num_rounds: communication rounds (the scan length)
    name:       display label (auto-generated when omitted)
    cohort:     optional ``repro.core.cohort.CohortSpec`` — the
                cross-device participation model, passed through to
                methods that take one (``"fednl-cohort"``); the ONE
                place a cell declares population/cohort/arrival instead
                of ad-hoc per-callsite kwargs. Also retargets the
                ``seconds_per_round`` traffic column onto the cohort's
                link and size.
    """

    method: str
    compressor: Optional[str] = None
    level: Optional[float] = None
    params: Mapping[str, Any] = field(default_factory=dict)
    seeds: Sequence[int] = (0,)
    num_rounds: int = 50
    name: Optional[str] = None
    cohort: Optional[Any] = None

    def __post_init__(self):
        object.__setattr__(self, "params", dict(self.params))
        object.__setattr__(self, "seeds", tuple(int(s) for s in self.seeds))

    @property
    def label(self) -> str:
        if self.name:
            return self.name
        parts = [self.method]
        if self.compressor:
            lvl = "" if self.level is None else f"{self.level:g}"
            parts.append(f"{self.compressor}{lvl}")
        if self.cohort is not None:
            pop = self.cohort.population
            parts.append(f"K{self.cohort.cohort}" +
                         (f"ofN{pop}" if pop is not None else ""))
        return ":".join(parts)

    def build(self, oracles: Oracles):
        """Instantiate the method object for this cell."""
        comp = (build_compressor(self.compressor, self.level)
                if self.compressor else None)
        params = dict(self.params)
        if self.cohort is not None:
            params["cohort"] = self.cohort
        return make_method(self.method, oracles, comp, **params)


@dataclass
class CellResult:
    spec: ExperimentSpec
    xs: np.ndarray        # (num_seeds, num_rounds+1, d) iterate history
    gaps: np.ndarray      # (num_seeds, num_rounds+1) f(x_k) - f*
    bits: np.ndarray      # (num_rounds+1,) cumulative bits/node (analytic)
    us_per_round: float   # cell wall-clock / num_rounds — END-TO-END cost
                          # including the one-time jit trace+compile (the
                          # quantity the engine optimizes vs serial loops),
                          # not steady-state per-round latency
    bits_measured: Optional[np.ndarray] = None
                          # (num_rounds+1,) cumulative bits/node, measured
                          # from the method's payload structure
    bits_entropy: Optional[np.ndarray] = None
                          # (num_rounds+1,) cumulative bits/node with the
                          # sparsifier index streams entropy-coded
                          # (log2 C(d^2, k) accounting, no actual codec)
    seconds_per_round: Optional[float] = None
                          # simulated uplink seconds per synchronous round:
                          # measured wire bits priced through the traffic
                          # model (Sweep's ``link`` preset, straggler max
                          # over the problem's n silos); None if link=None


@dataclass
class SweepResult:
    cells: list

    def records(self) -> list[dict]:
        return [row for c in self.cells for row in rec.cell_records(c)]

    def summary(self, target: Optional[float] = None) -> list[dict]:
        return rec.summary_records(self.cells, target)

    def cell(self, label: str) -> CellResult:
        for c in self.cells:
            if c.spec.label == label:
                return c
        raise KeyError(label)


# -- cell execution ------------------------------------------------------------


def batched_runner(method, n: int, num_rounds: int):
    """One jitted program per cell-shape: vmap over the seed axis of a
    scan over rounds. Hold on to the returned callable to amortize the
    trace across repeated executions (new x0, new seeds of the same
    count); method objects are rebuilt per Sweep.run, so caching here
    by method identity would never hit."""

    def one(x0, seed):
        state = method.init(x0, n, seed=seed)
        _, xs = scan_rounds(method, state, num_rounds)
        return xs

    return jax.jit(jax.vmap(one, in_axes=(None, 0)))


def run_cell(method, x0, n: int, num_rounds: int, seeds: Sequence[int]):
    """Execute one cell; returns (num_seeds, num_rounds+1, d) history."""
    runner = batched_runner(method, n, num_rounds)
    xs = runner(jnp.asarray(x0), jnp.asarray(seeds))
    x0b = jnp.broadcast_to(jnp.asarray(x0), (len(seeds), 1, x0.shape[-1]))
    return jnp.concatenate([x0b, xs], axis=1)


# -- the sweep runner ----------------------------------------------------------


class Sweep:
    """Run a grid of ``ExperimentSpec`` cells against one problem.

    ``problem`` (to ``run``) is a mapping with the benchmark-harness
    keys: "grad", "hess" (stacked per-silo oracles), optional "val" and
    "fstar" for gap curves, "n", "d", and optional "data"
    (``LogRegData``, required by the sharded path).

    ``link`` prices each cell's measured wire bits through the traffic
    model (``repro.wire.traffic`` preset name or ``LinkModel``) into the
    ``seconds_per_round`` record column; ``link=None`` skips the model
    (the column reads NaN).
    """

    def __init__(self, specs: Sequence[ExperimentSpec], mesh=None,
                 axis: str = "data", link="wan"):
        self.specs = list(specs)
        self.mesh = mesh
        self.axis = axis
        self.link = link

    def run(self, problem, x0=None) -> SweepResult:
        oracles = Oracles(value=problem.get("val"), grad=problem["grad"],
                          hess=problem["hess"])
        n, d = int(problem["n"]), int(problem["d"])
        fstar = problem.get("fstar")
        if x0 is None:
            x0 = jnp.zeros(d)
        cells = []
        for spec in self.specs:
            method = spec.build(oracles)
            t0 = time.perf_counter()
            if self.mesh is not None and self._shardable(spec, problem):
                xs = self._run_sharded(spec, problem, x0)
            else:
                xs = run_cell(method, x0, n, spec.num_rounds, spec.seeds)
            xs = jax.block_until_ready(xs)
            wall_us = (time.perf_counter() - t0) * 1e6
            val = problem.get("val")
            if val is not None:
                gaps = np.asarray(jax.vmap(jax.vmap(val))(xs))
                if fstar is not None:
                    gaps = gaps - fstar
            else:
                gaps = np.full(xs.shape[:2], np.nan)
            cells.append(CellResult(
                spec=spec,
                xs=np.asarray(xs),
                gaps=gaps,
                bits=rec.bits_curve(method, d, spec.num_rounds),
                bits_measured=rec.measured_bits_curve(
                    method, d, spec.num_rounds),
                bits_entropy=rec.entropy_bits_curve(
                    method, d, spec.num_rounds),
                us_per_round=wall_us / max(1, spec.num_rounds),
                seconds_per_round=self._cell_seconds(spec, method, d, n),
            ))
        return SweepResult(cells)

    def _cell_seconds(self, spec: ExperimentSpec, method, d: int,
                      n: int) -> Optional[float]:
        """Traffic-model pricing for one cell: a ``cohort=`` cell is
        priced on ITS link and cohort size (the round waits for the
        sampled K, not all N registered clients); everything else uses
        the sweep-wide ``link`` preset over the problem's n silos."""
        if spec.cohort is not None:
            return rec.seconds_per_round(method, d, spec.cohort.cohort,
                                         link=spec.cohort.link)
        if self.link is None:
            return None
        return rec.seconds_per_round(method, d, n, link=self.link)

    # -- shard_map path (reuses core/federated.py's mesh axis) -----------------

    def _shardable(self, spec: ExperimentSpec, problem) -> bool:
        if spec.method != "fednl" or problem.get("data") is None:
            return False
        return int(problem["n"]) % int(self.mesh.shape[self.axis]) == 0

    def _run_sharded(self, spec: ExperimentSpec, problem, x0):
        from ..core.federated import run_fednl_sharded

        comp = build_compressor(spec.compressor, spec.level)
        p = dict(spec.params)
        out = []
        for seed in spec.seeds:
            # defaults must match FedNL.__init__ so the same spec runs the
            # same algorithm with and without mesh=
            _, xs = run_fednl_sharded(
                problem["data"], comp, self.mesh, x0, spec.num_rounds,
                alpha=p.get("alpha", 1.0), option=p.get("option", 1),
                mu=p.get("mu", 0.0), axis=self.axis, seed=seed)
            out.append(xs)
        return jnp.stack(out)


def run_sweep(specs: Sequence[ExperimentSpec], problem, x0=None,
              mesh=None, axis: str = "data", link="wan") -> SweepResult:
    """Convenience wrapper: ``Sweep(specs, mesh, axis, link).run(...)``."""
    return Sweep(specs, mesh=mesh, axis=axis, link=link).run(problem, x0=x0)
