"""Pallas TPU kernels for FedNL's compute hot spots.

  block_topk      — block-local Top-K contractive compressor (Def 3.3 with
                    delta = k/b^2); the TPU-native replacement for global
                    Top-K (A.3.3).
  scatter_accum   — payload-space server aggregation: sum n silos' sparse
                    payloads into ONE dense accumulator (one-hot-matmul
                    scatter; backs ``Compressor.aggregate`` fast paths).
  hess_update     — fused H += alpha*S with the ||D - H||_F compression-
                    error reduction (l_i^k) in the same HBM pass.
  tiled_matmul    — MXU-tiled matmul used by the PowerSGD/Rank-R power
                    iteration (A.3.2's TPU form).
  flash_attention — causal online-softmax attention (serving fast path).

Every kernel ships an ops.py (jit'd wrapper with interpret fallback on
CPU) and a ref.py (pure-jnp oracle used by the allclose test sweeps).
"""
