"""Pallas TPU kernels for FedNL's compute hot spots.

  block_topk      — block-local Top-K contractive compressor (Def 3.3 with
                    delta = k/b^2); the TPU-native replacement for global
                    Top-K (A.3.3).
  scatter_accum   — payload-space server aggregation: sum n silos' sparse
                    payloads into ONE dense accumulator (one-hot-matmul
                    scatter; backs ``Compressor.aggregate`` fast paths).
  hess_update     — fused H += alpha*S with the ||D - H||_F compression-
                    error reduction (l_i^k) in the same HBM pass.
  tiled_matmul    — MXU-tiled matmul used by the PowerSGD/Rank-R power
                    iteration (A.3.2's TPU form).
  flash_attention — causal online-softmax attention (serving fast path).

Every kernel ships an ops.py (jit'd wrapper with interpret fallback on
CPU) and a ref.py (pure-jnp oracle used by the allclose test sweeps).
"""

# The per-program VMEM footprint budget every kernel dispatch honors:
# 8 MiB of the ~16 MiB/core TPU VMEM, leaving headroom for scratch and
# the pipeline's double buffering. Kernel ops dispatch on it (e.g.
# scatter_accum picks single-block vs output-tiled) and the
# ``vmem-budget`` static-analysis rule enforces it on every traced
# ``pallas_call``'s BlockSpec footprint.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024
