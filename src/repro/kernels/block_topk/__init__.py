from .ops import block_topk, block_topk_payload, diff_topk_payload
from .ref import (
    block_topk_payload_ref,
    block_topk_ref,
    diff_topk_payload_ref,
    payload_to_dense,
)


def analysis_targets():
    """Representative traced configs for the static-analysis sweep
    (``repro.analysis``): name -> lazy ClosedJaxpr + rule context. The
    Pallas body is forced (use_pallas/interpret) so the kernel is in
    the jaxpr on any backend — tracing never executes it. The fused
    diff->top-k target additionally carries ``dense_forbidden``: the
    no-dense-roundtrip rule then proves the dense (d, d) difference is
    absent from the fused uplink jaxpr outside kernel bodies."""
    import jax
    import jax.numpy as jnp

    x = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    return [
        {
            "name": "block_topk[512x512,k=32,b=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda m: block_topk(m, k=32, block=128,
                                     interpret=True))(x),
            "context": {"block": 128},
        },
        {
            "name": "block_topk_payload[512x512,k=32,b=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda m: block_topk_payload(m, k=32, block=128,
                                             use_pallas=True,
                                             interpret=True))(x),
            "context": {"block": 128},
        },
        {
            "name": "diff_topk_payload[512x512,k=32,b=128,fused]",
            "trace": lambda: jax.make_jaxpr(
                lambda a, b: diff_topk_payload(a, b, k=32, block=128,
                                               use_pallas=True,
                                               interpret=True))(x, x),
            "context": {"block": 128, "dense_forbidden": (512, 512)},
        },
    ]
