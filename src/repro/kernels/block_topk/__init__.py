from .ops import block_topk
from .ref import block_topk_ref
