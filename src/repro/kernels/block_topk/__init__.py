from .ops import block_topk, block_topk_payload
from .ref import block_topk_payload_ref, block_topk_ref, payload_to_dense
