"""Block-local Top-K compressor kernel.

Grid: one program per (bm, bn) tile held in VMEM. Per tile, keep the k
largest-magnitude entries and zero the rest. Instead of a sort (hostile
to the VPU), the k-th magnitude is found by ~32 rounds of bisection on
[0, max|x|] — each round is a full-tile compare+popcount, all
vector-friendly. Entries with |x| >= threshold survive.

The resulting operator is contractive with delta = k / (bm*bn) per
Definition 3.3 (contraction holds per tile; Frobenius norm is separable
across tiles) — see DESIGN.md §3.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_tile_kernel(x_ref, o_ref, *, k: int, iters: int = 32):
    x = x_ref[...]
    ax = jnp.abs(x).astype(jnp.float32)
    numel = ax.size

    if k >= numel:
        o_ref[...] = x
        return

    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        # too many survivors -> raise threshold
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    thr = hi  # count(ax >= hi) <= k <= count(ax >= lo)
    o_ref[...] = jnp.where(ax >= thr, x, jnp.zeros_like(x))


def block_topk_kernel(x: jax.Array, k: int, block: int = 128,
                      interpret: bool = False) -> jax.Array:
    """x: (M, N) with M, N multiples of ``block`` (ops.py pads)."""
    m, n = x.shape
    grid = (m // block, n // block)
    return pl.pallas_call(
        functools.partial(_topk_tile_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)
