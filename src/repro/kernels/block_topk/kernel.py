"""Block-local Top-K compressor kernels: dense-masked and payload-emitting.

Grid: one program per (bm, bn) tile held in VMEM. Per tile, keep the k
largest-magnitude entries and zero the rest. Instead of a sort (hostile
to the VPU), the k-th magnitude is found by ~32 rounds of bisection on
[0, max|x|] — each round is a full-tile compare+popcount, all
vector-friendly. Entries with |x| >= threshold survive.

``block_topk_kernel`` writes the dense masked tile back (the seed-era
output format). ``block_topk_payload_kernel`` emits the WIRE FORMAT
directly — per tile, k (value, in-tile flat index) pairs in flat order —
so the compressed uplink never materializes a dense (d, d) buffer. The
survivor compaction is scatter/sort-free: flat-order positions come from
two triangular-matmul cumsums and the k payload slots are gathered with
a one-hot contraction (MXU-friendly); empty slots carry index -1.

The resulting operator is contractive with delta = k / (bm*bn) per
Definition 3.3 (contraction holds per tile; Frobenius norm is separable
across tiles) — see DESIGN.md §3.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _topk_tile_kernel(x_ref, o_ref, *, k: int, iters: int = 32):
    x = x_ref[...]
    ax = jnp.abs(x).astype(jnp.float32)
    numel = ax.size

    if k >= numel:
        o_ref[...] = x
        return

    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        # too many survivors -> raise threshold
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    thr = hi  # count(ax >= hi) <= k <= count(ax >= lo)
    o_ref[...] = jnp.where(ax >= thr, x, jnp.zeros_like(x))


def block_topk_kernel(x: jax.Array, k: int, block: int = 128,
                      interpret: bool = False) -> jax.Array:
    """x: (M, N) with M, N multiples of ``block`` (ops.py pads)."""
    m, n = x.shape
    grid = (m // block, n // block)
    return pl.pallas_call(
        functools.partial(_topk_tile_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def _bisect_bracket(ax: jax.Array, k: int, iters: int):
    """Bisection bracket (lo, hi) on |x| with
    count(ax >= hi) <= k <= count(ax >= lo) (full-tile scalars)."""
    hi = jnp.max(ax)
    lo = jnp.zeros_like(hi)

    def body(_, lohi):
        lo, hi = lohi
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((ax >= mid).astype(jnp.int32))
        lo = jnp.where(cnt > k, mid, lo)
        hi = jnp.where(cnt > k, hi, mid)
        return lo, hi

    return jax.lax.fori_loop(0, iters, body, (lo, hi))


def _flat_positions(mask: jax.Array) -> jax.Array:
    """Flat-order exclusive position of each True entry, scatter/sort-
    free: within-row inclusive cumsum and row-offset cumsum as
    triangular matmuls (MXU work, no 1D scans). mask is (b0, b1) f32."""
    b0, b1 = mask.shape
    col = jax.lax.broadcasted_iota(jnp.float32, (b1, b1), 0)
    incl = jnp.dot(mask, (col <= jax.lax.broadcasted_iota(
        jnp.float32, (b1, b1), 1)).astype(jnp.float32),
        preferred_element_type=jnp.float32)         # (b0, b1)
    row = jax.lax.broadcasted_iota(jnp.float32, (b0, b0), 0)
    strict_lower = (jax.lax.broadcasted_iota(
        jnp.float32, (b0, b0), 1) < row).astype(jnp.float32)
    row_offset = jnp.dot(strict_lower, incl[:, b1 - 1:b1],
                         preferred_element_type=jnp.float32)  # (b0, 1)
    return row_offset + incl - mask                 # (b0, b1)


def _emit_topk_payload(x, vals_ref, idx_ref, *, k: int, iters: int = 32):
    """Shared payload-emission body: select the k largest-magnitude
    entries of the in-VMEM tile ``x`` and write the (1, k) value/index
    payload rows — used by both the plain top-k kernel and the fused
    diff->top-k kernel."""
    b0, b1 = x.shape
    ax = jnp.abs(x).astype(jnp.float32)

    # two-phase selection (exactly k entries, Def 3.3-preserving even
    # under ties): everything strictly above the bisection bracket
    # first, then boundary ties in flat order until k slots fill
    if k >= b0 * b1:
        strict = jnp.ones(x.shape, jnp.float32)
        tie = jnp.zeros(x.shape, jnp.float32)
    else:
        lo, hi = _bisect_bracket(ax, k, iters)
        strict = (ax >= hi).astype(jnp.float32)
        tie = (ax >= lo).astype(jnp.float32) * (1.0 - strict)

    n_strict = jnp.sum(strict)
    pos = jnp.where(strict > 0, _flat_positions(strict),
                    n_strict + _flat_positions(tie))  # (b0, b1)
    mask = strict + tie

    flat_ids = (jax.lax.broadcasted_iota(jnp.float32, (b0, b1), 0) * b1
                + jax.lax.broadcasted_iota(jnp.float32, (b0, b1), 1))

    # one-hot slot assignment: onehot[e, s] = 1 iff entry e fills slot s;
    # payload slots fill by a single (1, bb) @ (bb, k) dot each (tie
    # overflow has pos >= k and never matches a slot)
    slots = jax.lax.broadcasted_iota(jnp.float32, (b0 * b1, k), 1)
    onehot = ((pos.reshape(b0 * b1, 1) == slots)
              * mask.reshape(b0 * b1, 1))           # (bb, k) f32
    # one-hot contraction is exact (each slot sums one entry + zeros);
    # carry f64 through for f64 tiles (interpret mode), f32 otherwise
    acc = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
    vals = jnp.dot(x.reshape(1, b0 * b1).astype(acc), onehot.astype(acc),
                   preferred_element_type=acc)                  # (1, k)
    ids = jnp.dot(flat_ids.reshape(1, b0 * b1), onehot,
                  preferred_element_type=jnp.float32)           # (1, k)
    filled = jnp.dot(jnp.ones((1, b0 * b1), jnp.float32), onehot,
                     preferred_element_type=jnp.float32) > 0.0  # (1, k)

    vals_ref[...] = vals.astype(vals_ref.dtype)
    idx_ref[...] = jnp.where(filled, ids, -1.0).astype(jnp.int32)


def _topk_payload_tile_kernel(x_ref, vals_ref, idx_ref, *, k: int,
                              iters: int = 32):
    _emit_topk_payload(x_ref[...], vals_ref, idx_ref, k=k, iters=iters)


def _diff_topk_payload_tile_kernel(a_ref, b_ref, vals_ref, idx_ref, sq_ref,
                                   *, k: int, iters: int = 32):
    """Fused uplink tile: D = a - b is formed IN VMEM, its squared
    Frobenius partial written to the per-tile scalar cell, and its
    top-k payload emitted — the dense (d, d) difference never exists in
    HBM."""
    x = a_ref[...] - b_ref[...]                     # (b0, b1), VMEM only
    acc = jnp.float64 if x.dtype == jnp.float64 else jnp.float32
    xa = x.astype(acc)
    sq_ref[0, 0] = jnp.sum(xa * xa).astype(sq_ref.dtype)
    _emit_topk_payload(x, vals_ref, idx_ref, k=k, iters=iters)


def block_topk_payload_kernel(x: jax.Array, k: int, block: int = 128,
                              interpret: bool = False):
    """Payload-emitting variant: x (M, N) with M, N multiples of
    ``block``; returns (values, indices) of shape (nblocks, k), tiles in
    row-major grid order, entries in flat in-tile order, empty slots at
    index -1. ``k`` must be <= block**2 (ops.py clamps)."""
    m, n = x.shape
    gm, gn = m // block, n // block
    grid = (gm, gn)
    vals, idx = pl.pallas_call(
        functools.partial(_topk_payload_tile_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((block, block), lambda i, j: (i, j))],
        out_specs=(
            pl.BlockSpec((1, k), lambda i, j: (i * gn + j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i * gn + j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((gm * gn, k), x.dtype),
            jax.ShapeDtypeStruct((gm * gn, k), jnp.int32),
        ),
        interpret=interpret,
    )(x)
    return vals, idx


def diff_topk_payload_kernel(a: jax.Array, b: jax.Array, k: int,
                             block: int = 128, interpret: bool = False):
    """Fused diff->top-k->payload: a, b (M, N) with M, N multiples of
    ``block`` (ops.py pads); per tile computes D = a - b in VMEM,
    selects its top-k, and emits (values, indices) of shape
    (nblocks, k) plus the per-tile squared Frobenius partials
    (nblocks, 1) — summing them gives ||D||_F^2 for free (the l_i
    FedNL ships with each payload). The dense difference never
    round-trips through HBM."""
    m, n = a.shape
    gm, gn = m // block, n // block
    grid = (gm, gn)
    tile = pl.BlockSpec((block, block), lambda i, j: (i, j))
    row = pl.BlockSpec((1, k), lambda i, j: (i * gn + j, 0))
    acc = jnp.float64 if a.dtype == jnp.float64 else jnp.float32
    vals, idx, sq = pl.pallas_call(
        functools.partial(_diff_topk_payload_tile_kernel, k=k),
        grid=grid,
        in_specs=[tile, tile],
        out_specs=(
            row, row,
            pl.BlockSpec((1, 1), lambda i, j: (i * gn + j, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((gm * gn, k), a.dtype),
            jax.ShapeDtypeStruct((gm * gn, k), jnp.int32),
            jax.ShapeDtypeStruct((gm * gn, 1), acc),
        ),
        interpret=interpret,
    )(a, b)
    return vals, idx, sq
