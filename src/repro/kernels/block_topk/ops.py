"""Jit'd wrappers: pad to block multiples, dispatch to the Pallas
kernels (interpret=True on CPU so the kernel body itself is what runs).

``block_topk`` returns the dense masked matrix (seed-era format);
``block_topk_payload`` returns the wire format — per-tile (values,
indices) arrays matching ``repro.core.compressors.BlockSparsePayload``
— without ever materializing the dense compressed matrix."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import block_topk_kernel, block_topk_payload_kernel


@partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(x: jax.Array, k: int, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    out = block_topk_kernel(xp, k=k, block=block, interpret=interpret)
    return out[:m, :n] if (pm or pn) else out


@partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk_payload(x: jax.Array, k: int, block: int = 128,
                       interpret: bool | None = None):
    """Compressed payload of ``x``: (values, indices), both
    (ceil(m/block) * ceil(n/block), min(k, block**2)); tiles in row-major
    grid order, in-tile flat indices, empty slots at index -1."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    k = min(k, block * block)
    return block_topk_payload_kernel(xp, k=k, block=block,
                                     interpret=interpret)
