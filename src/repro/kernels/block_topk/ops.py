"""Dispatching wrappers: pad to block multiples, dispatch to the Pallas
kernels (interpret=True on CPU so the kernel body itself is what runs).

``block_topk`` returns the dense masked matrix (seed-era format);
``block_topk_payload`` returns the wire format — per-tile (values,
indices) arrays matching ``repro.core.compressors.BlockSparsePayload``
— without ever materializing the dense compressed matrix.
``diff_topk_payload`` is the fused uplink: D = a - b is computed
tile-wise INSIDE the kernel, its top-k payload emitted directly along
with ||D||_F^2, so the dense difference never round-trips through HBM.

On TPU the payload ops run the Pallas kernels; elsewhere the sort-based
jnp oracle IS the fast path (interpret-mode Pallas would run the kernel
body at interpreter speed inside every optimizer step). The two paths
agree exactly on tie-free data; under bisection-resolution ties the
kernel keeps boundary ties in flat order while the oracle keeps the
sort order — both exactly k entries per tile. A tuned
``repro.kernels.tuning`` cache entry overrides the backend rule when
the caller passes ``use_pallas=None`` (explicit argument > cache >
backend default); resolution happens in the plain-Python wrapper so a
freshly warmed cache applies at the next trace."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..tuning import lookup
from .kernel import (
    block_topk_kernel,
    block_topk_payload_kernel,
    diff_topk_payload_kernel,
)
from .ref import block_topk_payload_ref, diff_topk_payload_ref


@partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(x: jax.Array, k: int, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    out = block_topk_kernel(xp, k=k, block=block, interpret=interpret)
    return out[:m, :n] if (pm or pn) else out


def _resolve_use_pallas(op: str, use_pallas, shape, k: int, block: int,
                        dtype) -> bool:
    if use_pallas is not None:
        return bool(use_pallas)
    cfg = lookup(op, shape=shape, k=k, n=block, dtype=dtype)
    if cfg is not None and cfg.use_pallas is not None:
        return bool(cfg.use_pallas)
    return jax.default_backend() == "tpu"


def block_topk_payload(x: jax.Array, k: int, block: int = 128,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None):
    """Compressed payload of ``x``: (values, indices), both
    (ceil(m/block) * ceil(n/block), min(k, block**2)); tiles in row-major
    grid order, in-tile flat indices, empty slots at index -1. Pallas
    kernel on TPU, jnp oracle elsewhere (see module docstring; a tuned
    cache entry overrides); tests force the kernel body with
    ``use_pallas=True, interpret=True``."""
    k = min(int(k), block * block)
    use_pallas = _resolve_use_pallas("block_topk_payload", use_pallas,
                                     x.shape, k, block, x.dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _block_topk_payload_impl(x, k=k, block=block,
                                    use_pallas=use_pallas,
                                    interpret=bool(interpret))


@partial(jax.jit, static_argnames=("k", "block", "use_pallas",
                                   "interpret"))
def _block_topk_payload_impl(x, k: int, block: int, use_pallas: bool,
                             interpret: bool):
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    if not use_pallas:
        return block_topk_payload_ref(xp, k=k, block=block)
    return block_topk_payload_kernel(xp, k=k, block=block,
                                     interpret=interpret)


def diff_topk_payload(a: jax.Array, b: jax.Array, k: int, block: int = 128,
                      use_pallas: bool | None = None,
                      interpret: bool | None = None):
    """Fused uplink payload of D = a - b: returns (values, indices,
    sumsq) where values/indices are the Block-TopK payload of the
    difference (same layout as ``block_topk_payload``) and sumsq is the
    scalar ||D||_F^2 (per-tile partials summed — padding tiles are
    zero), so the l_i = ||D||_F every FedNL variant ships comes out of
    the same pass. On the Pallas path the dense (d, d) difference is
    never materialized — each tile's diff lives only in VMEM."""
    k = min(int(k), block * block)
    use_pallas = _resolve_use_pallas("diff_topk_payload", use_pallas,
                                     a.shape, k, block, a.dtype)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _diff_topk_payload_impl(a, b, k=k, block=block,
                                   use_pallas=use_pallas,
                                   interpret=bool(interpret))


@partial(jax.jit, static_argnames=("k", "block", "use_pallas",
                                   "interpret"))
def _diff_topk_payload_impl(a, b, k: int, block: int, use_pallas: bool,
                            interpret: bool):
    dt = jnp.result_type(a.dtype, b.dtype)
    a = a.astype(dt)
    b = b.astype(dt)
    m, n = a.shape
    pm, pn = (-m) % block, (-n) % block
    if pm or pn:
        a = jnp.pad(a, ((0, pm), (0, pn)))
        b = jnp.pad(b, ((0, pm), (0, pn)))
    if use_pallas:
        vals, idx, sq = diff_topk_payload_kernel(a, b, k=k, block=block,
                                                 interpret=interpret)
    else:
        vals, idx, sq = diff_topk_payload_ref(a, b, k=k, block=block)
    return vals, idx, jnp.sum(sq)
