"""Jit'd wrappers: pad to block multiples, dispatch to the Pallas
kernels (interpret=True on CPU so the kernel body itself is what runs).

``block_topk`` returns the dense masked matrix (seed-era format);
``block_topk_payload`` returns the wire format — per-tile (values,
indices) arrays matching ``repro.core.compressors.BlockSparsePayload``
— without ever materializing the dense compressed matrix. On TPU the
payload op runs the Pallas kernel; elsewhere the sort-based jnp oracle
IS the fast path (interpret-mode Pallas would run the kernel body at
interpreter speed inside every optimizer step). The two paths agree
exactly on tie-free data; under bisection-resolution ties the kernel
keeps boundary ties in flat order while the oracle keeps the sort
order — both exactly k entries per tile."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import block_topk_kernel, block_topk_payload_kernel
from .ref import block_topk_payload_ref


@partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(x: jax.Array, k: int, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    out = block_topk_kernel(xp, k=k, block=block, interpret=interpret)
    return out[:m, :n] if (pm or pn) else out


@partial(jax.jit, static_argnames=("k", "block", "use_pallas",
                                   "interpret"))
def block_topk_payload(x: jax.Array, k: int, block: int = 128,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None):
    """Compressed payload of ``x``: (values, indices), both
    (ceil(m/block) * ceil(n/block), min(k, block**2)); tiles in row-major
    grid order, in-tile flat indices, empty slots at index -1. Pallas
    kernel on TPU, jnp oracle elsewhere (see module docstring); tests
    force the kernel body with ``use_pallas=True, interpret=True``."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    k = min(k, block * block)
    if not use_pallas:
        return block_topk_payload_ref(xp, k=k, block=block)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return block_topk_payload_kernel(xp, k=k, block=block,
                                     interpret=interpret)
