"""Jit'd wrapper: pads to block multiples, dispatches to the Pallas
kernel (interpret=True on CPU so the kernel body itself is what runs)."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import block_topk_kernel


@partial(jax.jit, static_argnames=("k", "block", "interpret"))
def block_topk(x: jax.Array, k: int, block: int = 128,
               interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = x.shape
    pm, pn = (-m) % block, (-n) % block
    xp = jnp.pad(x, ((0, pm), (0, pn))) if (pm or pn) else x
    out = block_topk_kernel(xp, k=k, block=block, interpret=interpret)
    return out[:m, :n] if (pm or pn) else out
