"""Pure-jnp oracles: exact per-block Top-K via jax.lax.top_k, in dense
and payload (values + indices) form, plus the payload -> dense
reconstruction used by tests and the server side."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def _tiles(x: jax.Array, block: int):
    m, n = x.shape
    assert m % block == 0 and n % block == 0
    nb0, nb1 = m // block, n // block
    return x.reshape(nb0, block, nb1, block).transpose(0, 2, 1, 3) \
        .reshape(nb0 * nb1, block * block)


def block_topk_ref(x: jax.Array, k: int, block: int = 128) -> jax.Array:
    m, n = x.shape
    nb0, nb1 = m // block, n // block
    tiles = _tiles(x, block)
    kk = min(k, block * block)
    _, idx = jax.lax.top_k(jnp.abs(tiles), kk)
    vals = jnp.take_along_axis(tiles, idx, axis=1)
    out = jnp.zeros_like(tiles)
    out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)
    return out.reshape(nb0, nb1, block, block).transpose(0, 2, 1, 3) \
        .reshape(m, n)


def block_topk_payload_ref(x: jax.Array, k: int, block: int = 128):
    """(values, indices) per tile, in the payload kernel's layout:
    row-major tiles, entries sorted by in-tile flat index."""
    tiles = _tiles(x, block)
    kk = min(k, block * block)
    _, idx = jax.lax.top_k(jnp.abs(tiles), kk)
    idx = jnp.sort(idx, axis=1)  # kernel compaction emits flat order
    vals = jnp.take_along_axis(tiles, idx, axis=1)
    return vals, idx.astype(jnp.int32)


def diff_topk_payload_ref(a: jax.Array, b: jax.Array, k: int,
                          block: int = 128):
    """Unfused oracle of the fused uplink: form D = a - b dense, take
    its block payload, and return (values, indices, per-tile squared
    Frobenius partials) in the kernel's layout."""
    d = a - b
    vals, idx = block_topk_payload_ref(d, k=k, block=block)
    acc = jnp.float64 if d.dtype == jnp.float64 else jnp.float32
    da = _tiles(d, block).astype(acc)
    sq = jnp.sum(da * da, axis=1, keepdims=True)
    return vals, idx, sq


def payload_to_dense(vals: jax.Array, idx: jax.Array, shape,
                     block: int = 128) -> jax.Array:
    """Reconstruct the dense compressed matrix from a (values, indices)
    payload (either kernel or ref layout); -1 indices are dropped.
    Delegates to the one block-sparse decoder in core.compressors —
    the kernel payload IS a BlockSparsePayload."""
    from repro.core.compressors import BlockSparsePayload, BlockTopK

    codec = BlockTopK(k_per_block=int(vals.shape[-1]), block=block)
    return codec.decompress(BlockSparsePayload(values=vals, indices=idx),
                            shape)
