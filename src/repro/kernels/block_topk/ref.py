"""Pure-jnp oracle: exact per-block Top-K via jax.lax.top_k."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def block_topk_ref(x: jax.Array, k: int, block: int = 128) -> jax.Array:
    m, n = x.shape
    assert m % block == 0 and n % block == 0
    nb0, nb1 = m // block, n // block
    tiles = x.reshape(nb0, block, nb1, block).transpose(0, 2, 1, 3) \
        .reshape(nb0 * nb1, block * block)
    kk = min(k, block * block)
    _, idx = jax.lax.top_k(jnp.abs(tiles), kk)
    vals = jnp.take_along_axis(tiles, idx, axis=1)
    out = jnp.zeros_like(tiles)
    out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)
    return out.reshape(nb0, nb1, block, block).transpose(0, 2, 1, 3) \
        .reshape(m, n)
