from .ops import flash_attention
from .ref import flash_attention_ref


def analysis_targets():
    """Representative traced config for the static-analysis sweep: the
    causal online-softmax serving path. Pallas body forced;
    trace-only."""
    import jax
    import jax.numpy as jnp

    q = jax.ShapeDtypeStruct((1, 384, 2, 64), jnp.float32)
    return [
        {
            "name": "flash_attention[T=384,bq=bk=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda a, b, c: flash_attention(a, b, c, bq=128, bk=128,
                                                interpret=True))(q, q, q),
            "context": {},
        },
    ]
