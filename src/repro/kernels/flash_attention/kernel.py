"""Causal flash attention (forward) — online-softmax tiling.

Grid (batch*heads, T/bq); each program streams the key/value blocks
j <= i for its query block, keeping running (max, sum, acc) statistics in
VMEM scratch. This is the TPU-native replacement for materializing the
(T, T) score matrix; the serving path uses it for long-context prefill.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq: int, bk: int,
                  scale: float):
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale            # (bq, hd)
    hd = q.shape[-1]

    m0 = jnp.full((bq,), -1e30, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    acc0 = jnp.zeros((bq, hd), jnp.float32)

    q_pos = qi * bq + jnp.arange(bq)
    n_kblocks = (qi * bq) // bk + 1                        # causal: j <= i

    def body(j, carry):
        m, l, acc = carry
        k = pl.load(k_ref, (pl.dslice(j * bk, bk), slice(None))) \
            .astype(jnp.float32)                           # (bk, hd)
        v = pl.load(v_ref, (pl.dslice(j * bk, bk), slice(None))) \
            .astype(jnp.float32)
        s = q @ k.T                                        # (bq, bk)
        k_pos = j * bk + jnp.arange(bk)
        mask = k_pos[None, :] <= q_pos[:, None]
        s = jnp.where(mask, s, -1e30)

        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return m_new, l_new, acc_new

    m, l, acc = jax.lax.fori_loop(0, n_kblocks, body, (m0, l0, acc0))
    o_ref[...] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q: jax.Array, k: jax.Array, v: jax.Array,
                           bq: int = 128, bk: int = 128,
                           interpret: bool = False) -> jax.Array:
    """q, k, v: (BH, T, hd); causal. T must be a multiple of bq and bk."""
    bh, t, hd = q.shape
    scale = 1.0 / (hd ** 0.5)
    grid = (bh, t // bq)
    return pl.pallas_call(
        functools.partial(_flash_kernel, bq=bq, bk=bk, scale=scale),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((None, t, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((None, t, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(q, k, v)
