from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_kernel


@partial(jax.jit, static_argnames=("bq", "bk", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None) -> jax.Array:
    """Causal attention over (B, T, H, hd) (GQA groups pre-expanded by the
    caller). Pads T to the block size; padded keys are masked by causality
    (they sit at positions > every real query)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, t, h, hd = q.shape
    pad = (-t) % max(bq, bk)
    if pad:
        zeros = lambda x: jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q, k, v = zeros(q), zeros(k), zeros(v)
    tp = t + pad
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, tp, hd)
    out = flash_attention_kernel(fold(q), fold(k), fold(v), bq=bq, bk=bk,
                                 interpret=interpret)
    out = out.reshape(b, h, tp, hd).transpose(0, 2, 1, 3)
    return out[:, :t]
