"""Oracle: dense causal softmax attention."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    bh, t, hd = q.shape
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / (hd ** 0.5)
    mask = jnp.tril(jnp.ones((t, t), bool))
    s = jnp.where(mask[None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", w, v.astype(jnp.float32)).astype(q.dtype)
