from .ops import hess_update
from .ref import hess_update_ref


def analysis_targets():
    """Representative traced config for the static-analysis sweep: the
    fused H += alpha*S + ||D - H||_F pass. Pallas body forced;
    trace-only."""
    import jax
    import jax.numpy as jnp

    m = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    return [
        {
            "name": "hess_update[512x512,b=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda h, d, s: hess_update(h, d, s, 0.5, block=128,
                                            interpret=True))(m, m, m),
            "context": {"block": 128},
        },
    ]
