from .ops import hess_update
from .ref import hess_update_ref
