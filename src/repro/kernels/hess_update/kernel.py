"""Fused FedNL device-side Hessian bookkeeping (Algorithm 1 lines 5-6).

Per round each device must produce, from the same d x d tiles:

    l_i^k       = || H_i^k - D^k ||_F          (D = local Hessian at x^k)
    H_i^{k+1}   = H_i^k + alpha * S^k          (S = compressed diff)

Doing the norm and the update in separate passes streams H twice from
HBM; this kernel fuses both into one pass: per-(bm,bn) tile it writes the
updated tile and accumulates the squared-error partial into a per-tile
scratch cell (summed by the ops wrapper — a (grid,) reduction is cheap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hess_update_kernel(h_ref, d_ref, s_ref, o_ref, err_ref, *, alpha: float):
    h = h_ref[...]
    d = d_ref[...]
    s = s_ref[...]
    diff = (h - d).astype(jnp.float32)
    err_ref[0, 0] = jnp.sum(diff * diff)
    o_ref[...] = h + alpha * s


def hess_update_kernel(h: jax.Array, d: jax.Array, s: jax.Array, alpha: float,
                       block: int = 128, interpret: bool = False):
    """Any (m, n): edge tiles are zero-padded to the block grid here
    (the grid used to be ``m // block`` which silently DROPPED non-
    multiple edges), then cropped from the output — the padding is zero
    in h, d, and s alike, so its diff contributes exactly 0 to the
    error partials and nothing to the cropped update."""
    m, n = h.shape
    pm, pn = (-m) % block, (-n) % block
    if pm or pn:
        pad = lambda x: jnp.pad(x, ((0, pm), (0, pn)))
        h, d, s = pad(h), pad(d), pad(s)
    mp, np_ = h.shape
    grid = (mp // block, np_ // block)
    tile = pl.BlockSpec((block, block), lambda i, j: (i, j))
    out, err = pl.pallas_call(
        functools.partial(_hess_update_kernel, alpha=alpha),
        grid=grid,
        in_specs=[tile, tile, tile],
        out_specs=[tile, pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[
            jax.ShapeDtypeStruct(h.shape, h.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(h, d, s)
    if pm or pn:
        out = out[:m, :n]
    return out, err
