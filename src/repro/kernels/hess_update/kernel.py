"""Fused FedNL device-side Hessian bookkeeping (Algorithm 1 lines 5-6).

Per round each device must produce, from the same d x d tiles:

    l_i^k       = || H_i^k - D^k ||_F          (D = local Hessian at x^k)
    H_i^{k+1}   = H_i^k + alpha * S^k          (S = compressed diff)

Doing the norm and the update in separate passes streams H twice from
HBM; this kernel fuses both into one pass: per-(bm,bn) tile it writes the
updated tile and accumulates the squared-error partial into a per-tile
scratch cell (summed by the ops wrapper — a (grid,) reduction is cheap).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hess_update_kernel(h_ref, d_ref, s_ref, o_ref, err_ref, *, alpha: float):
    h = h_ref[...]
    d = d_ref[...]
    s = s_ref[...]
    diff = (h - d).astype(jnp.float32)
    err_ref[0, 0] = jnp.sum(diff * diff)
    o_ref[...] = h + alpha * s


def hess_update_kernel(h: jax.Array, d: jax.Array, s: jax.Array, alpha: float,
                       block: int = 128, interpret: bool = False):
    m, n = h.shape
    grid = (m // block, n // block)
    tile = pl.BlockSpec((block, block), lambda i, j: (i, j))
    out, err = pl.pallas_call(
        functools.partial(_hess_update_kernel, alpha=alpha),
        grid=grid,
        in_specs=[tile, tile, tile],
        out_specs=[tile, pl.BlockSpec((1, 1), lambda i, j: (i, j))],
        out_shape=[
            jax.ShapeDtypeStruct(h.shape, h.dtype),
            jax.ShapeDtypeStruct(grid, jnp.float32),
        ],
        interpret=interpret,
    )(h, d, s)
    return out, err
