from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from ..tuning import lookup
from .kernel import hess_update_kernel


def hess_update(h: jax.Array, d: jax.Array, s: jax.Array, alpha: float,
                block: int | None = None, interpret: bool | None = None):
    """Returns (H + alpha*S, ||H - D||_F) in one fused pass. Any (m, n)
    — edge tiles are padded/masked in the kernel wrapper. ``block``
    resolution: explicit argument > tuned winner
    (``repro.kernels.tuning``, keyed on (d-bucket, dtype, device)) >
    the untuned 128 default; resolved here in plain Python so a warmed
    cache applies at the next trace."""
    if block is None:
        cfg = lookup("hess_update", shape=h.shape, dtype=h.dtype)
        block = cfg.block if cfg is not None and cfg.block else 128
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return _hess_update_impl(h, d, s, alpha, block=int(block),
                             interpret=bool(interpret))


@partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def _hess_update_impl(h, d, s, alpha: float, block: int, interpret: bool):
    out, err = hess_update_kernel(h, d, s, alpha, block=block,
                                  interpret=interpret)
    return out, jnp.sqrt(jnp.sum(err))
