from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import hess_update_kernel


@partial(jax.jit, static_argnames=("alpha", "block", "interpret"))
def hess_update(h: jax.Array, d: jax.Array, s: jax.Array, alpha: float,
                block: int = 128, interpret: bool | None = None):
    """Returns (H + alpha*S, ||H - D||_F). Pads to block multiples."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, n = h.shape
    pm, pn = (-m) % block, (-n) % block
    if pm or pn:
        pad = lambda x: jnp.pad(x, ((0, pm), (0, pn)))
        h_p, d_p, s_p = pad(h), pad(d), pad(s)
    else:
        h_p, d_p, s_p = h, d, s
    out, err = hess_update_kernel(h_p, d_p, s_p, alpha, block=block,
                                  interpret=interpret)
    if pm or pn:
        out = out[:m, :n]
    return out, jnp.sqrt(jnp.sum(err))
