"""Oracle: unfused two-pass version."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def hess_update_ref(h: jax.Array, d: jax.Array, s: jax.Array, alpha: float):
    diff = (h - d).astype(jnp.float32)
    l = jnp.sqrt(jnp.sum(diff * diff))
    return h + alpha * s, l
