from .kernel import scatter_accum_tiled_kernel
from .ops import block_scatter_accumulate, scatter_accumulate
from .ref import block_scatter_accumulate_ref, scatter_accumulate_ref
