from .kernel import scatter_accum_tiled_kernel
from .ops import block_scatter_accumulate, scatter_accumulate
from .ref import block_scatter_accumulate_ref, scatter_accumulate_ref


def analysis_targets():
    """Representative traced configs for the static-analysis sweep:
    both dispatch regimes of ``scatter_accumulate`` (single-block and
    VMEM-tiled — the tiled shape would blow the budget single-block)
    plus the block-sparse path. Pallas bodies forced; trace-only."""
    import jax
    import jax.numpy as jnp

    def pair(n, k):
        return (jax.ShapeDtypeStruct((n, k), jnp.float32),
                jax.ShapeDtypeStruct((n, k), jnp.int32))

    v_s, i_s = pair(4, 512)
    v_t, i_t = pair(4, 2048)
    v_b = jax.ShapeDtypeStruct((3, 16, 64), jnp.float32)
    i_b = jax.ShapeDtypeStruct((3, 16, 64), jnp.int32)
    return [
        {
            "name": "scatter_accumulate[512x512,single-block]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (512, 512), use_pallas=True,
                    interpret=True))(v_s, i_s),
            "context": {},
        },
        {
            "name": "scatter_accumulate[4096x4096,tiled]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (4096, 4096), use_pallas=True,
                    interpret=True))(v_t, i_t),
            "context": {},
        },
        {
            "name": "scatter_accumulate[1024x1024,symmetric-fused]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (1024, 1024), use_pallas=True,
                    interpret=True, symmetric=True))(v_s, i_s),
            "context": {},
        },
        {
            "name": "block_scatter_accumulate[4x4 grid,b=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: block_scatter_accumulate(
                    v, i, (4, 4), 128, use_pallas=True,
                    interpret=True))(v_b, i_b),
            "context": {"block": 128},
        },
    ]
