from .kernel import scatter_accum_tiled_kernel
from .ops import (
    block_scatter_accumulate,
    scatter_accumulate,
    silo_chunk_for,
    streamed_scatter_accumulate,
    streamed_slab_update,
)
from .ref import block_scatter_accumulate_ref, scatter_accumulate_ref
from .sharded import (
    mirror_expand_pairs,
    row_window_scatter,
    sharded_scatter_accumulate,
)


def analysis_targets():
    """Representative traced configs for the static-analysis sweep:
    both dispatch regimes of ``scatter_accumulate`` (single-block and
    VMEM-tiled — the tiled shape would blow the budget single-block),
    the block-sparse path, the streamed silo-slab update (the
    cross-device server's inner kernel: one slab + the running
    accumulator, VMEM-bounded regardless of n), and the sharded
    row-window scatter (row0 traced, as under shard_map). Pallas bodies
    forced; trace-only."""
    import jax
    import jax.numpy as jnp

    def pair(n, k):
        return (jax.ShapeDtypeStruct((n, k), jnp.float32),
                jax.ShapeDtypeStruct((n, k), jnp.int32))

    v_s, i_s = pair(4, 512)
    v_t, i_t = pair(4, 2048)
    v_b = jax.ShapeDtypeStruct((3, 16, 64), jnp.float32)
    i_b = jax.ShapeDtypeStruct((3, 16, 64), jnp.int32)
    return [
        {
            "name": "scatter_accumulate[512x512,single-block]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (512, 512), use_pallas=True,
                    interpret=True))(v_s, i_s),
            "context": {},
        },
        {
            "name": "scatter_accumulate[4096x4096,tiled]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (4096, 4096), use_pallas=True,
                    interpret=True))(v_t, i_t),
            "context": {},
        },
        {
            "name": "scatter_accumulate[1024x1024,symmetric-fused]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: scatter_accumulate(
                    v, i, (1024, 1024), use_pallas=True,
                    interpret=True, symmetric=True))(v_s, i_s),
            "context": {},
        },
        {
            "name": "streamed_slab_update[4096x4096,tiled,slab=4]",
            "trace": lambda: jax.make_jaxpr(
                lambda a, v, i: streamed_slab_update(
                    a, v, i, (4096, 4096), interpret=True,
                    tile=(512, 512), chunk=512))(
                jax.ShapeDtypeStruct((4096, 4096), jnp.float32),
                v_t, i_t),
            "context": {},
        },
        {
            "name": "row_window_scatter[1024-row window of 4096x4096]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i, r0: row_window_scatter(
                    v, i, (4096, 4096), r0, 1024, use_pallas=True,
                    interpret=True))(
                v_t, i_t, jax.ShapeDtypeStruct((), jnp.int32)),
            "context": {},
        },
        {
            "name": "block_scatter_accumulate[4x4 grid,b=128]",
            "trace": lambda: jax.make_jaxpr(
                lambda v, i: block_scatter_accumulate(
                    v, i, (4, 4), 128, use_pallas=True,
                    interpret=True))(v_b, i_b),
            "context": {"block": 128},
        },
    ]
