"""Pallas scatter-accumulate kernels — the server side of the FedNL
uplink in payload space.

The server's job per round is S = sum_i S_i where each S_i arrives as a
sparse payload (values + indices). Instead of decompressing every silo
to a dense (d, d) and meaning the (n, d, d) stack, these kernels keep
ONE dense accumulator and scatter every silo's pairs into it.

TPU VPUs have no native scatter, so the scatter is recast as MXU work:
for a chunk of entries, build two one-hot matrices from the decomposed
(row, col) indices — R[e, r] = [row_e == r] with the value folded in,
C[e, c] = [col_e == c] — and the chunk's dense contribution is the
matmul R^T @ C (each output cell sums exactly the entries addressing
it, so accumulation of duplicate indices is automatic and exact in the
accumulate dtype). Payload padding (index -1) yields row_e = -1, which
matches no row one-hot and contributes zero.

``scatter_accum_kernel``: global flat indices, grid over (silo, chunk)
programs all revisiting the same full-matrix output block (init at
program 0, accumulate after) — the standard Pallas revisiting-output
reduction. Fits VMEM for d up to ~1500 f32 only; ops.py dispatches to
it when the whole accumulator fits a VMEM budget.

``scatter_accum_tiled_kernel``: the same chunked pair stream, but the
output is a 2-D grid of (tm, tn) tiles with the chunk axis innermost —
each (row-tile, col-tile) program streams every (silo, chunk) pair and
contributes only its in-window entries (the index range test is free:
tile-local coordinates outside [0, tile) match no one-hot column). Only
ONE output tile is ever resident in VMEM, so arbitrary d scales; each
pair is re-examined once per tile, which is the classic compute-for-
memory trade of a tiled scatter (the one-hot matmuls are MXU work
either way).

``block_scatter_accum_kernel``: in-tile indices, one program per output
tile, contraction over all n*k of that tile's pairs in one matmul pair.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _acc_dtype(dtype):
    return jnp.float64 if dtype == jnp.float64 else jnp.float32


def _onehot_contribution(vals, rows, cols, d0: int, d1: int, acc):
    """Dense (d0, d1) sum of entries vals[e] at (rows[e], cols[e]) via
    two one-hot matmuls; negative rows match nothing (padding)."""
    ck = vals.shape[-1]
    r2 = rows.reshape(ck, 1)
    c2 = cols.reshape(ck, 1)
    rio = jax.lax.broadcasted_iota(jnp.int32, (ck, d0), 1)
    cio = jax.lax.broadcasted_iota(jnp.int32, (ck, d1), 1)
    r_onehot = (r2 == rio).astype(acc) * vals.reshape(ck, 1).astype(acc)
    c_onehot = (c2 == cio).astype(acc)
    return jax.lax.dot_general(
        r_onehot, c_onehot,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=acc)                     # (d0, d1)


def _mirror_vals(vals, rows, cols):
    """Values for the mirrored (col, row) contribution of a symmetric
    scatter: diagonal entries (row == col) are zeroed so they land
    exactly once — together with the direct contribution this fuses the
    ``c + c.T - diag(diag(c))`` second pass into the kernel. Padding
    (row = -1, col >= 0) never equals its col and keeps its value, but
    its mirrored *column* index is negative and matches no one-hot."""
    return jnp.where(rows == cols, jnp.zeros_like(vals), vals)


def _chunk_contribution(vals, idx, *, d1: int, row0, col0, tm: int,
                        tn: int, symmetric: bool):
    """Dense (tm, tn) window contribution of one (1, ck) pair chunk.

    ``row0``/``col0`` shift into window-local coordinates (0 for the
    single-block kernel, the tile origin for the tiled one): entries
    outside the window — including -1 padding, whose row is negative —
    match no one-hot column and contribute zero. ``symmetric`` adds each
    off-diagonal entry's mirror through the identical window test."""
    rows = idx // d1                                    # -1 -> -1 (no match)
    cols = idx - rows * d1
    acc = _acc_dtype(vals.dtype)
    contrib = _onehot_contribution(vals, rows - row0, cols - col0,
                                   tm, tn, acc)
    if symmetric:
        contrib += _onehot_contribution(_mirror_vals(vals, rows, cols),
                                        cols - row0, rows - col0,
                                        tm, tn, acc)
    return contrib


def _scatter_accum_tile_kernel(vals_ref, idx_ref, out_ref, *, d1: int,
                               symmetric: bool = False):
    """One (value, index) chunk of one silo; all programs revisit the
    same full-matrix out block. ``d1`` is the UNPADDED column count the
    flat indices were built against."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    d0p, d1p = out_ref.shape
    contrib = _chunk_contribution(vals_ref[...], idx_ref[...], d1=d1,
                                  row0=0, col0=0, tm=d0p, tn=d1p,
                                  symmetric=symmetric)
    out_ref[...] += contrib.astype(out_ref.dtype)


def _scatter_accum_tile_init_kernel(vals_ref, idx_ref, init_ref, out_ref,
                                    *, d1: int, symmetric: bool = False):
    """Streaming variant of ``_scatter_accum_tile_kernel``: program 0
    seeds the output block from a caller-provided accumulator instead of
    zeros, so a slab of silos continues the running server sum in the
    exact same add order as one stacked pass over all silos."""
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _():
        out_ref[...] = init_ref[...]

    d0p, d1p = out_ref.shape
    contrib = _chunk_contribution(vals_ref[...], idx_ref[...], d1=d1,
                                  row0=0, col0=0, tm=d0p, tn=d1p,
                                  symmetric=symmetric)
    out_ref[...] += contrib.astype(out_ref.dtype)


def scatter_accum_kernel(values: jax.Array, indices: jax.Array,
                         out_shape, d1: int,
                         interpret: bool = False,
                         symmetric: bool = False,
                         init: jax.Array | None = None) -> jax.Array:
    """values/indices: (nchunks, ck) — silo payloads flattened into
    fixed-size chunks (ops.py pads with value 0 / index -1). Returns the
    (d0p, d1p) = ``out_shape`` dense SUM; ``d1`` is the unpadded column
    count of the matrix the flat indices address. ``symmetric`` adds
    each off-diagonal entry's mirror in the same pass (lower-triangular
    payloads: the fused symmetric-TopK server sum). ``init`` seeds the
    accumulator with a prior (d0p, d1p) partial sum (the streamed path's
    running total) instead of zeros."""
    nchunks, ck = values.shape
    if init is None:
        return pl.pallas_call(
            functools.partial(_scatter_accum_tile_kernel, d1=d1,
                              symmetric=symmetric),
            grid=(nchunks,),
            in_specs=[
                pl.BlockSpec((1, ck), lambda i: (i, 0)),
                pl.BlockSpec((1, ck), lambda i: (i, 0)),
            ],
            out_specs=pl.BlockSpec(out_shape, lambda i: (0, 0)),
            out_shape=jax.ShapeDtypeStruct(out_shape, values.dtype),
            interpret=interpret,
        )(values, indices)
    return pl.pallas_call(
        functools.partial(_scatter_accum_tile_init_kernel, d1=d1,
                          symmetric=symmetric),
        grid=(nchunks,),
        in_specs=[
            pl.BlockSpec((1, ck), lambda i: (i, 0)),
            pl.BlockSpec((1, ck), lambda i: (i, 0)),
            pl.BlockSpec(out_shape, lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec(out_shape, lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct(out_shape, values.dtype),
        interpret=interpret,
    )(values, indices, init)


def _scatter_accum_tiled_tile_kernel(vals_ref, idx_ref, out_ref, *, d1: int,
                                     symmetric: bool = False):
    """One (row-tile, col-tile, chunk) program: contribute this chunk's
    in-window entries to the (tm, tn) output tile. The chunk axis is the
    innermost grid dim, so each output tile is revisited consecutively
    over the whole (silo, chunk) pair stream while staying resident in
    VMEM — the accumulator never exists as one full (d0, d1) block."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _():
        out_ref[...] = jnp.zeros_like(out_ref)

    tm, tn = out_ref.shape
    contrib = _chunk_contribution(vals_ref[...], idx_ref[...], d1=d1,
                                  row0=pl.program_id(0) * tm,
                                  col0=pl.program_id(1) * tn,
                                  tm=tm, tn=tn, symmetric=symmetric)
    out_ref[...] += contrib.astype(out_ref.dtype)


def _scatter_accum_tiled_tile_init_kernel(vals_ref, idx_ref, init_ref,
                                          out_ref, *, d1: int,
                                          symmetric: bool = False):
    """Streaming variant of ``_scatter_accum_tiled_tile_kernel``: each
    output tile's first chunk program copies the matching tile of a
    caller-provided accumulator instead of zeroing, so slabs of silos
    chain with the identical per-tile add order as one stacked pass."""
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _():
        out_ref[...] = init_ref[...]

    tm, tn = out_ref.shape
    contrib = _chunk_contribution(vals_ref[...], idx_ref[...], d1=d1,
                                  row0=pl.program_id(0) * tm,
                                  col0=pl.program_id(1) * tn,
                                  tm=tm, tn=tn, symmetric=symmetric)
    out_ref[...] += contrib.astype(out_ref.dtype)


def scatter_accum_tiled_kernel(values: jax.Array, indices: jax.Array,
                               out_shape, d1: int, tile,
                               interpret: bool = False,
                               symmetric: bool = False,
                               init: jax.Array | None = None) -> jax.Array:
    """Tiled variant of ``scatter_accum_kernel``: same (nchunks, ck)
    chunked pair stream, but the output is produced as a 2-D grid of
    (tm, tn) = ``tile`` blocks so VMEM holds one tile, not the matrix.
    ``out_shape`` must be a multiple of ``tile`` in both dims (ops.py
    pads); ``d1`` is the unpadded column count the flat indices address.
    ``symmetric`` mirrors off-diagonal entries in the same pass — the
    mirrored coordinates go through the identical tile-window test, so
    each mirror lands in exactly the tile that owns it. ``init`` seeds
    each output tile from the matching tile of a prior (d0p, d1p)
    partial sum (the streamed path's running total) instead of zeros.
    """
    nchunks, ck = values.shape
    d0p, d1p = (int(s) for s in out_shape)
    tm, tn = (int(t) for t in tile)
    assert d0p % tm == 0 and d1p % tn == 0, (out_shape, tile)
    if init is None:
        return pl.pallas_call(
            functools.partial(_scatter_accum_tiled_tile_kernel, d1=d1,
                              symmetric=symmetric),
            grid=(d0p // tm, d1p // tn, nchunks),
            in_specs=[
                pl.BlockSpec((1, ck), lambda i, j, c: (c, 0)),
                pl.BlockSpec((1, ck), lambda i, j, c: (c, 0)),
            ],
            out_specs=pl.BlockSpec((tm, tn), lambda i, j, c: (i, j)),
            out_shape=jax.ShapeDtypeStruct((d0p, d1p), values.dtype),
            interpret=interpret,
        )(values, indices)
    return pl.pallas_call(
        functools.partial(_scatter_accum_tiled_tile_init_kernel, d1=d1,
                          symmetric=symmetric),
        grid=(d0p // tm, d1p // tn, nchunks),
        in_specs=[
            pl.BlockSpec((1, ck), lambda i, j, c: (c, 0)),
            pl.BlockSpec((1, ck), lambda i, j, c: (c, 0)),
            pl.BlockSpec((tm, tn), lambda i, j, c: (i, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, c: (i, j)),
        out_shape=jax.ShapeDtypeStruct((d0p, d1p), values.dtype),
        interpret=interpret,
    )(values, indices, init)


def _block_scatter_tile_kernel(vals_ref, idx_ref, out_ref, *, block: int):
    """One output tile: scatter all n silos' k pairs for this tile in a
    single one-hot matmul pair (contraction over n*k)."""
    vals = vals_ref[...]                                # (n, 1, k)
    idx = idx_ref[...]                                  # (n, 1, k) int32
    n, _, k = vals.shape
    flat_v = vals.reshape(1, n * k)
    flat_i = idx.reshape(1, n * k)
    rows = flat_i // block                              # -1 -> -1 (no match)
    cols = flat_i - rows * block
    acc = _acc_dtype(vals.dtype)
    contrib = _onehot_contribution(flat_v, rows, cols, block, block, acc)
    out_ref[...] = contrib.astype(out_ref.dtype)


def block_scatter_accum_kernel(values: jax.Array, indices: jax.Array,
                               grid, block: int,
                               interpret: bool = False) -> jax.Array:
    """values/indices: (n, nblocks, k) in the BlockSparsePayload layout
    (row-major tiles, in-tile flat indices, -1 padding); nblocks must
    equal gm*gn. Returns the (gm*block, gn*block) dense SUM."""
    gm, gn = (int(g) for g in grid)
    n, nblk, k = values.shape
    assert nblk == gm * gn, (nblk, grid)
    return pl.pallas_call(
        functools.partial(_block_scatter_tile_kernel, block=block),
        grid=(gm, gn),
        in_specs=[
            pl.BlockSpec((n, 1, k), lambda i, j: (0, i * gn + j, 0)),
            pl.BlockSpec((n, 1, k), lambda i, j: (0, i * gn + j, 0)),
        ],
        out_specs=pl.BlockSpec((block, block), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * block, gn * block),
                                       values.dtype),
        interpret=interpret,
    )(values, indices)
