"""Dispatching wrappers for payload-space scatter-accumulation.

One op per payload family, both returning the dense SUM over silos from
ONE accumulator (the caller divides by n for the server mean):

  scatter_accumulate        — SparsePayload: global flat indices
  block_scatter_accumulate  — BlockSparsePayload: per-tile indices

On TPU the Pallas kernels run; elsewhere the pure-jnp oracle (a single
XLA scatter-add) IS the fast path — interpret-mode Pallas would emulate
the kernel body at Python speed on the hot loop of every step. Tests
force the kernel body with ``use_pallas=True, interpret=True``.

Config resolution (``tile``, ``chunk``) is explicit argument > tuned
winner (``repro.kernels.tuning`` cache, keyed on (d-bucket, k, n,
dtype, device kind)) > untuned default (``_TILE``/``_CHUNK`` with the
VMEM-budget single-block-vs-tiled dispatch). Resolution happens in the
plain-Python wrapper BEFORE the jitted impl, so a cache warmed between
calls takes effect on the next trace instead of being baked forever at
the first one."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .. import VMEM_BUDGET_BYTES
from ..tuning import lookup
from .kernel import (
    block_scatter_accum_kernel,
    scatter_accum_kernel,
    scatter_accum_tiled_kernel,
)
from .ref import block_scatter_accumulate_ref, scatter_accumulate_ref

_CHUNK = 512  # default (value, index) pairs per kernel program

# Single-block vs tiled dispatch: the single-block kernel holds the
# whole padded accumulator in ONE VMEM block, which is only legal while
# it fits the shared kernel budget (8 MiB of the ~16 MiB/core VMEM,
# leaving room for the chunk one-hots); beyond it the tiled kernel
# streams the pair stream per (tm, tn) output tile, so arbitrary d
# scales. The constant lives in ``repro.kernels`` so the vmem-budget
# analysis rule and the dispatch agree by construction.
_VMEM_ACC_BUDGET_BYTES = VMEM_BUDGET_BYTES
_TILE = (512, 512)  # default tiled-path output block (1 MiB f32)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def scatter_accumulate(values: jax.Array, indices: jax.Array, shape,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None,
                       tile=None, chunk: int | None = None,
                       symmetric: bool = False) -> jax.Array:
    """Dense (d0, d1) SUM of n sparse silo payloads.

    values/indices: (n, k) per-silo (value, row-major flat index) pairs
    into ``shape``; -1 indices (payload padding) are dropped; duplicate
    indices accumulate. On the Pallas path the accumulator lives in ONE
    VMEM block while the padded matrix fits ``_VMEM_ACC_BUDGET_BYTES``
    and is otherwise tiled into (tm, tn) output blocks (the chunk pair
    stream replayed per tile) — any d stays in VMEM. ``tile`` forces
    the tiled kernel with that (tm, tn) block (tm a multiple of 8, tn
    of 128) and ``chunk`` the pair-stream chunk length; leaving BOTH
    None consults the autotuner cache first, then budget-dispatches
    with the defaults. ``symmetric`` treats each payload as the lower
    triangle of a symmetric matrix and lands every off-diagonal entry
    at (r, c) AND (c, r) in the same kernel pass — the fused
    ``c + c.T - diag(diag(c))`` used by symmetric TopK aggregation."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return scatter_accumulate_ref(values, indices, shape,
                                      symmetric=symmetric)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, k = values.shape
    if tile is None and chunk is None:  # untuned call: cache decides
        cfg = lookup("scatter_accumulate", shape=shape, k=k, n=n,
                     dtype=values.dtype)
        if cfg is not None:
            tile, chunk = cfg.tile, cfg.chunk
    if chunk is None:
        chunk = _CHUNK
    shape = tuple(int(s) for s in shape)
    tile = (int(tile[0]), int(tile[1])) if tile is not None else None
    return _scatter_accumulate_pallas(values, indices, shape,
                                      interpret=bool(interpret), tile=tile,
                                      chunk=int(chunk),
                                      symmetric=bool(symmetric))


@partial(jax.jit, static_argnames=("shape", "interpret", "tile", "chunk",
                                   "symmetric"))
def _scatter_accumulate_pallas(values, indices, shape, interpret: bool,
                               tile, chunk: int,
                               symmetric: bool) -> jax.Array:
    d0, d1 = shape
    n, k = values.shape
    kp = _round_up(max(k, 1), chunk) if k > chunk else max(k, 1)
    ck = min(kp, chunk)
    vals = jnp.pad(values, ((0, 0), (0, kp - k)))
    idx = jnp.pad(indices, ((0, 0), (0, kp - k)), constant_values=-1)
    # fixed-size chunks -> one grid program each, revisiting the output
    nchunks = n * (kp // ck)
    vals = vals.reshape(nchunks, ck)
    idx = idx.reshape(nchunks, ck)
    acc_bytes = (_round_up(d0, 8) * _round_up(d1, 128)
                 * jnp.dtype(values.dtype).itemsize)
    if tile is None and acc_bytes > _VMEM_ACC_BUDGET_BYTES:
        # over budget the single-block kernel is illegal no matter what
        # a cache entry says — the budget guard outranks the tuner
        tile = _TILE
    if tile is None:
        d0p, d1p = _round_up(d0, 8), _round_up(d1, 128)
        out = scatter_accum_kernel(vals, idx, (d0p, d1p), d1,
                                   interpret=interpret,
                                   symmetric=symmetric)
    else:
        tm = _round_up(int(tile[0]), 8)
        tn = _round_up(int(tile[1]), 128)
        d0p, d1p = _round_up(d0, tm), _round_up(d1, tn)
        out = scatter_accum_tiled_kernel(vals, idx, (d0p, d1p), d1,
                                         (tm, tn), interpret=interpret,
                                         symmetric=symmetric)
    return out[:d0, :d1]


@partial(jax.jit, static_argnames=("shape", "interpret", "tile", "chunk",
                                   "symmetric"))
def streamed_slab_update(acc, values, indices, shape,
                         interpret: bool = False, tile=None,
                         chunk: int = _CHUNK,
                         symmetric: bool = False) -> jax.Array:
    """One streamed silo-slab update of the running server sum.

    ``acc`` is the PADDED (d0p, d1p) accumulator (zeros before the first
    slab); ``values``/``indices`` are one (m, k) slab of the stacked
    silo payloads. Chunks the slab exactly as the stacked Pallas path
    chunks the full stack and seeds the kernel's output block from
    ``acc`` — so chaining slabs replays the identical per-cell add
    sequence as ONE stacked pass, and the result is bitwise equal.
    Traceable: the analysis sweep checks vmem-budget on this jaxpr (the
    slab, not n, bounds what the kernel stages into VMEM)."""
    d0, d1 = (int(s) for s in shape)
    m, k = values.shape
    chunk = int(chunk)
    kp = _round_up(max(k, 1), chunk) if k > chunk else max(k, 1)
    ck = min(kp, chunk)
    vals = jnp.pad(values, ((0, 0), (0, kp - k)))
    idx = jnp.pad(indices, ((0, 0), (0, kp - k)), constant_values=-1)
    nchunks = m * (kp // ck)
    vals = vals.reshape(nchunks, ck)
    idx = idx.reshape(nchunks, ck)
    if tile is None:
        return scatter_accum_kernel(vals, idx, acc.shape, d1,
                                    interpret=interpret,
                                    symmetric=symmetric, init=acc)
    return scatter_accum_tiled_kernel(vals, idx, acc.shape, d1, tile,
                                      interpret=interpret,
                                      symmetric=symmetric, init=acc)


@partial(jax.jit, static_argnames=("shape",))
def _streamed_ref_slab(acc, values, indices, shape) -> jax.Array:
    """One silo-slab scatter into the running (d0, d1) accumulator on
    the portable path. The symmetric mirror is NOT applied here — the
    caller mirrors ONCE after the last slab (mirroring per slab would
    change the add association and break bitwise equality)."""
    return scatter_accumulate_ref(values, indices, shape,
                                  symmetric=False, init=acc)


def silo_chunk_for(k: int, value_dtype, index_dtype=jnp.int32) -> int:
    """Largest silo-slab size whose (value, index) pair stream fits the
    shared kernel VMEM budget — the streaming rule: stream once
    n * k * pair_bytes outgrows ``VMEM_BUDGET_BYTES``."""
    pair = (jnp.dtype(value_dtype).itemsize
            + jnp.dtype(index_dtype).itemsize)
    return max(1, int(VMEM_BUDGET_BYTES // max(1, int(k) * pair)))


def streamed_scatter_accumulate(values, indices, shape,
                                silo_chunk: int | None = None,
                                use_pallas: bool | None = None,
                                interpret: bool | None = None,
                                tile=None, chunk: int | None = None,
                                symmetric: bool = False) -> jax.Array:
    """Dense (d0, d1) SUM of n sparse silo payloads, streamed over silo
    slabs from host memory — bitwise equal to ``scatter_accumulate`` on
    the same stack, at bounded device footprint.

    The stacked path stages the whole (n, k) pair stream; once
    n * k * pair_bytes outgrows the VMEM budget the server must not.
    This wrapper cuts the stack into ``silo_chunk``-silo slabs (default:
    the largest slab whose pair stream fits ``VMEM_BUDGET_BYTES``),
    stages each slab with ``jax.device_put`` — the NEXT slab's transfer
    is issued before blocking on the current slab's kernel, so the copy
    double-buffers behind the compute — and chains the slab kernels
    through their ``init`` accumulator. Kernel config (tile, chunk) is
    resolved ONCE against the FULL stacked problem so every slab runs
    the identical kernel the stacked path would pick; device memory
    holds one padded accumulator plus at most two slabs, independent of
    n. ``values``/``indices`` may be numpy (host) or jax arrays."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    n, k = values.shape
    shape = tuple(int(s) for s in shape)
    d0, d1 = shape
    if silo_chunk is None:
        silo_chunk = silo_chunk_for(k, values.dtype, indices.dtype)
    silo_chunk = max(1, int(silo_chunk))
    if tile is None and chunk is None:  # untuned: full-n cache key
        cfg = lookup("scatter_accumulate", shape=shape, k=k, n=n,
                     dtype=values.dtype)
        if cfg is not None:
            tile, chunk = cfg.tile, cfg.chunk
    if chunk is None:
        chunk = _CHUNK
    chunk = int(chunk)

    starts = list(range(0, n, silo_chunk))

    def fetch(s: int):
        e = min(s + silo_chunk, n)
        return (jax.device_put(values[s:e]), jax.device_put(indices[s:e]))

    if not use_pallas:
        acc = jnp.zeros(shape, values.dtype)
        nxt = fetch(starts[0])
        for pos, _ in enumerate(starts):
            cur_v, cur_i = nxt
            if pos + 1 < len(starts):
                nxt = fetch(starts[pos + 1])
            acc = _streamed_ref_slab(acc, cur_v, cur_i, shape)
        if symmetric:
            acc = acc + acc.T - jnp.diag(jnp.diag(acc))
        return acc

    acc_bytes = (_round_up(d0, 8) * _round_up(d1, 128)
                 * jnp.dtype(values.dtype).itemsize)
    if tile is None and acc_bytes > _VMEM_ACC_BUDGET_BYTES:
        tile = _TILE  # budget guard outranks the tuner, as in the stacked path
    if tile is None:
        d0p, d1p = _round_up(d0, 8), _round_up(d1, 128)
    else:
        tile = (_round_up(int(tile[0]), 8), _round_up(int(tile[1]), 128))
        d0p, d1p = _round_up(d0, tile[0]), _round_up(d1, tile[1])
    acc = jnp.zeros((d0p, d1p), values.dtype)
    nxt = fetch(starts[0])
    for pos, _ in enumerate(starts):
        cur_v, cur_i = nxt
        if pos + 1 < len(starts):
            nxt = fetch(starts[pos + 1])
        acc = streamed_slab_update(acc, cur_v, cur_i, shape,
                                   interpret=bool(interpret), tile=tile,
                                   chunk=chunk,
                                   symmetric=bool(symmetric))
    return acc[:d0, :d1]


@partial(jax.jit, static_argnames=("grid", "block", "use_pallas",
                                   "interpret"))
def block_scatter_accumulate(values: jax.Array, indices: jax.Array, grid,
                             block: int,
                             use_pallas: bool | None = None,
                             interpret: bool | None = None) -> jax.Array:
    """Dense (gm*block, gn*block) SUM of n block-sparse silo payloads
    ((n, nblocks, k) values/indices, BlockSparsePayload layout)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if not use_pallas:
        return block_scatter_accumulate_ref(values, indices, grid, block)
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    return block_scatter_accum_kernel(values, indices, grid, block,
                                      interpret=interpret)
