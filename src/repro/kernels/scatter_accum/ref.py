"""Pure-jnp oracles for payload-space server accumulation: sum n silos'
sparse payloads into ONE dense accumulator (never an (n, d, d) stack).
These are also the portable fast path on non-TPU backends — a single
XLA scatter-add over all (value, index) pairs — while the Pallas
kernels in kernel.py are the TPU path; ops.py dispatches."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def scatter_accumulate_ref(values: jax.Array, indices: jax.Array,
                           shape, symmetric: bool = False,
                           init: jax.Array | None = None) -> jax.Array:
    """Dense (d0, d1) SUM of n sparse silo payloads.

    values/indices: (n, k) — per-silo (value, global flat index) pairs,
    row-major indices into ``shape``; -1/out-of-range indices (payload
    padding) are dropped. Duplicate indices (across silos, or within
    one after ties) accumulate additively — exactly the server sum.
    Negative indices are remapped BEFORE the scatter (jax normalizes
    them ahead of the mode="drop" bounds check). ``symmetric`` mirrors
    lower-triangular payloads (``c + c.T - diag(diag(c))`` — the
    two-pass oracle for the kernel's fused mirror). ``init`` seeds the
    accumulator with a prior (d0, d1) partial sum: the streamed path
    scatters each silo slab into the running total, which keeps the
    per-cell add order identical to one scatter over the whole stacked
    stream (the symmetric mirror must then be applied by the caller ONCE
    after the last slab, never per slab)."""
    d0, d1 = (int(s) for s in shape)
    n_out = d0 * d1
    idx = jnp.where(indices < 0, n_out, indices).reshape(-1)
    acc = (jnp.zeros((n_out,), values.dtype) if init is None
           else init.reshape(n_out).astype(values.dtype))
    flat = acc.at[idx].add(values.reshape(-1), mode="drop")
    out = flat.reshape(d0, d1)
    if symmetric:
        out = out + out.T - jnp.diag(jnp.diag(out))
    return out


def block_scatter_accumulate_ref(values: jax.Array, indices: jax.Array,
                                 grid, block: int) -> jax.Array:
    """Dense (gm*block, gn*block) SUM of n block-sparse silo payloads.

    values/indices: (n, nblocks, k) — per-tile (value, in-tile flat
    index) pairs with tiles in row-major grid order (the
    ``BlockSparsePayload`` layout); nblocks must equal gm*gn. One
    (nblocks, block^2) accumulator total: each tile scatter-adds all
    n*k of its pairs, then tiles are laid back into the dense grid."""
    gm, gn = (int(g) for g in grid)
    bb = block * block
    nblk = values.shape[-2]
    v = jnp.moveaxis(values, -2, 0).reshape(nblk, -1)   # (nblk, n*k)
    i = jnp.moveaxis(indices, -2, 0).reshape(nblk, -1)
    i = jnp.where(i < 0, bb, i)
    tiles = jax.vmap(
        lambda vv, ii: jnp.zeros((bb,), values.dtype).at[ii].add(
            vv, mode="drop"))(v, i)
    return tiles.reshape(gm, gn, block, block).transpose(0, 2, 1, 3) \
        .reshape(gm * block, gn * block)
