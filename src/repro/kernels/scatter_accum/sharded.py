"""Mesh-sharded server accumulator: per-device row-tile ownership.

The streamed path (ops.py) bounds what ONE device stages per slab, but
the dense (d0, d1) accumulator itself still lives whole on every
device. Here the accumulator is sharded over a mesh axis instead: each
device owns a contiguous [row0, row0 + rows_per) row window and
scatters ONLY the pairs that land in its window (the payload stream is
replicated — payloads are tiny, the accumulator is what scales with d).
Aggregate capacity then grows with the device slice, not one chip's
HBM, and the output is born sharded ``P(axis, None)`` — ready to feed a
row-sharded Newton solve without a gather.

Out-of-window pairs are remapped to the -1 padding sentinel, so each
window scatter is the ordinary ``scatter_accumulate`` dispatch (ref or
Pallas kernel) at (rows_per, d1). Per accumulator cell, exactly one
device sees exactly the stacked stream's contributions in stream order,
so the gathered result equals the unsharded sum bitwise on the ref
path.

The symmetric (lower-triangular payload) sum cannot use the kernels'
fused per-window mirror — an entry's mirror may belong to a DIFFERENT
device's window — so the pair stream is mirror-expanded to (n, 2k)
before sharding: each off-diagonal entry appears once as (r, c) and
once as (c, r); diagonal and padding mirrors are sent to -1. This file
must not import ``repro.launch`` (launch imports models; kernels stay
leaf-level) — the placement helper ``accumulator_spec`` lives in
``launch/sharding.py``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

try:  # jax >= 0.6 exports shard_map at top level
    from jax import shard_map as _shard_map
except ImportError:  # pragma: no cover - depends on installed jax
    from jax.experimental.shard_map import shard_map as _shard_map

from .ops import scatter_accumulate


def row_window_scatter(values: jax.Array, indices: jax.Array, shape,
                       row0, rows_per: int,
                       use_pallas: bool | None = None,
                       interpret: bool | None = None,
                       tile=None, chunk: int | None = None) -> jax.Array:
    """Dense (rows_per, d1) SUM of the pairs whose row lands in
    [row0, row0 + rows_per); everything else — including -1 padding,
    whose row decomposes negative — becomes the -1 sentinel and is
    dropped by the scatter. ``row0`` may be traced (it is
    ``axis_index * rows_per`` inside ``shard_map``)."""
    d0, d1 = (int(s) for s in shape)
    rows = indices // d1                                # -1 -> -1
    cols = indices - rows * d1
    local = rows - row0
    in_window = (indices >= 0) & (local >= 0) & (local < rows_per)
    local_idx = jnp.where(in_window, local * d1 + cols, -1)
    return scatter_accumulate(values, local_idx, (int(rows_per), d1),
                              use_pallas=use_pallas, interpret=interpret,
                              tile=tile, chunk=chunk)


def mirror_expand_pairs(values: jax.Array, indices: jax.Array, d1: int):
    """(n, k) lower-triangular pairs -> (n, 2k) symmetric pairs: each
    off-diagonal entry once at (r, c) and once at (c, r). Diagonal
    mirrors AND padding mirrors are forced to the -1 sentinel — a
    mirrored padding index can decompose to a non-negative flat index,
    and even a zero-valued diagonal mirror would add 0.0 to a cell the
    unsharded sum never touches twice."""
    rows = indices // d1
    cols = indices - rows * d1
    off_diag = (indices >= 0) & (rows != cols)
    mirror_idx = jnp.where(off_diag, cols * d1 + rows, -1)
    return (jnp.concatenate([values, values], axis=-1),
            jnp.concatenate([indices, mirror_idx], axis=-1))


def sharded_scatter_accumulate(values: jax.Array, indices: jax.Array,
                               shape, mesh: Mesh, axis: str = "data",
                               use_pallas: bool | None = None,
                               interpret: bool | None = None,
                               tile=None, chunk: int | None = None,
                               symmetric: bool = False) -> jax.Array:
    """Dense (d0, d1) SUM of n sparse silo payloads with the
    accumulator sharded ``P(axis, None)`` over ``mesh``: each device
    owns d0 / mesh.shape[axis] contiguous rows and scatters only its
    in-window pairs. Requires d0 divisible by the axis extent (pad d0
    at the caller otherwise). ``symmetric`` mirror-expands the pair
    stream BEFORE sharding (see ``mirror_expand_pairs``) — the fused
    in-kernel mirror cannot cross window boundaries."""
    d0, d1 = (int(s) for s in shape)
    ndev = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    if d0 % ndev != 0:
        raise ValueError(
            f"sharded accumulator needs d0 % mesh[{axis!r}] == 0, "
            f"got d0={d0}, extent={ndev}")
    rows_per = d0 // ndev
    if symmetric:
        values, indices = mirror_expand_pairs(values, indices, d1)

    def window(v, i):
        row0 = jax.lax.axis_index(axis) * rows_per
        return row_window_scatter(v, i, (d0, d1), row0, rows_per,
                                  use_pallas=use_pallas,
                                  interpret=interpret, tile=tile,
                                  chunk=chunk)

    # check_rep=False: the per-device body may lower to a pallas_call,
    # which the replication checker has no rule for; the out_specs
    # already state the (axis, None) layout exactly.
    return _shard_map(window, mesh=mesh, in_specs=(P(), P()),
                      out_specs=P(axis, None),
                      check_rep=False)(values, indices)
