from .ops import tiled_matmul, powersgd_rank_r
from .ref import tiled_matmul_ref, powersgd_rank_r_ref
