from .ops import powersgd_rank_r, tiled_matmul
from .ref import powersgd_rank_r_ref, tiled_matmul_ref


def analysis_targets():
    """Representative traced configs for the static-analysis sweep: the
    MXU-tiled matmul and the PowerSGD subspace iteration built on it.
    Pallas bodies forced; trace-only."""
    import jax
    import jax.numpy as jnp

    a = jax.ShapeDtypeStruct((384, 256), jnp.float32)
    b = jax.ShapeDtypeStruct((256, 384), jnp.float32)
    m = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    return [
        {
            "name": "tiled_matmul[384x256 @ 256x384]",
            "trace": lambda: jax.make_jaxpr(
                lambda x, y: tiled_matmul(x, y, interpret=True))(a, b),
            "context": {},
        },
        {
            "name": "powersgd_rank_r[512x512,r=2]",
            "trace": lambda: jax.make_jaxpr(
                lambda x: powersgd_rank_r(x, 2, interpret=True))(m),
            "context": {},
        },
    ]
