"""MXU-tiled matmul — the compute core of the PowerSGD / Rank-R power
iteration (M @ Q and M^T @ P are the hot loops of the paper's preferred
compressor at scale).

Grid (M/bm, N/bn, K/bk); the K axis is the innermost (sequential) grid
dimension, accumulating into the output tile in fp32 — MXU dims aligned
to 128 by the ops wrapper's padding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref):
    # The output tile is revisited across the (sequential, innermost) K
    # grid axis and accumulated in fp32 (the out_shape dtype).
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...].astype(jnp.float32),
        b_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )


def tiled_matmul_kernel(a: jax.Array, b: jax.Array, bm: int = 128,
                        bn: int = 128, bk: int = 128,
                        interpret: bool = False) -> jax.Array:
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    grid = (m // bm, n // bn, k // bk)
    return pl.pallas_call(
        _matmul_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, l: (i, l)),
            pl.BlockSpec((bk, bn), lambda i, j, l: (l, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, l: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(a, b)
