from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import tiled_matmul_kernel


def _pad2(x, bm, bn):
    m, n = x.shape
    pm, pn = (-m) % bm, (-n) % bn
    if pm or pn:
        x = jnp.pad(x, ((0, pm), (0, pn)))
    return x, (m, n)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def tiled_matmul(a: jax.Array, b: jax.Array, bm: int = 128, bn: int = 128,
                 bk: int = 128, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    a_p, (m, _) = _pad2(a, bm, bk)
    b_p, (_, n) = _pad2(b, bk, bn)
    out = tiled_matmul_kernel(a_p, b_p, bm=bm, bn=bn, bk=bk,
                              interpret=interpret)
    return out[:m, :n].astype(a.dtype)


def powersgd_rank_r(m: jax.Array, r: int, iters: int = 2, seed: int = 0,
                    interpret: bool | None = None) -> jax.Array:
    """Rank-R compression by subspace iteration with the Pallas matmul as
    the compute core (QR stays in jnp — it is O(d r^2), not the hot loop)."""
    d1 = m.shape[1]
    q = jax.random.normal(jax.random.PRNGKey(seed), (d1, r), jnp.float32)
    q, _ = jnp.linalg.qr(q)
    m32 = m.astype(jnp.float32)
    for _ in range(iters):
        p, _ = jnp.linalg.qr(tiled_matmul(m32, q, interpret=interpret))
        q, _ = jnp.linalg.qr(tiled_matmul(m32.T, p, interpret=interpret))
    p = tiled_matmul(m32, q, interpret=interpret)
    return tiled_matmul(p, q.T, interpret=interpret).astype(m.dtype)
