"""Oracles: plain jnp matmul and SVD-free rank-R power iteration."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def tiled_matmul_ref(a: jax.Array, b: jax.Array) -> jax.Array:
    return jnp.dot(a.astype(jnp.float32), b.astype(jnp.float32)).astype(a.dtype)


def powersgd_rank_r_ref(m: jax.Array, r: int, iters: int = 2,
                        seed: int = 0) -> jax.Array:
    """Reference subspace iteration using jnp matmuls + QR."""
    d1 = m.shape[1]
    q = jax.random.normal(jax.random.PRNGKey(seed), (d1, r), jnp.float32)
    q, _ = jnp.linalg.qr(q)
    m32 = m.astype(jnp.float32)
    for _ in range(iters):
        p, _ = jnp.linalg.qr(m32 @ q)
        q, _ = jnp.linalg.qr(m32.T @ p)
    p = m32 @ q
    return (p @ q.T).astype(m.dtype)
