"""Kernel autotuning: measured dispatch configs for the Pallas ops.

``cache``  — the ``(op, d-bucket, k, n, dtype, device kind)`` ->
             ``KernelConfig`` store (in-memory + persisted JSON,
             ``$REPRO_TUNING_CACHE`` pins one for CI).
``tuner``  — candidate generation (VMEM-budget filtered), roofline
             pruning, wall-clock measurement, winner recording.

The ops in ``scatter_accum``, ``block_topk``, and ``hess_update``
consult ``lookup`` at trace time whenever the caller passes no explicit
config: explicit argument > cached winner > untuned default.
"""

from .cache import (
    CACHE_ENV,
    KernelConfig,
    TuningCache,
    bucket,
    cache_key,
    device_kind,
    get_cache,
    lookup,
    record,
    set_cache,
)
from .tuner import (
    autotune_block_topk_payload,
    autotune_diff_topk_payload,
    autotune_hess_update,
    autotune_scatter_accumulate,
    hess_candidates,
    predict_scatter_us,
    scatter_candidates,
    time_us,
)

__all__ = [
    "CACHE_ENV", "KernelConfig", "TuningCache", "bucket", "cache_key",
    "device_kind", "get_cache", "lookup", "record", "set_cache",
    "autotune_block_topk_payload", "autotune_diff_topk_payload",
    "autotune_hess_update", "autotune_scatter_accumulate",
    "hess_candidates", "predict_scatter_us", "scatter_candidates",
    "time_us", "analysis_targets",
]


def _parse_key(key: str):
    op, d_part, k_part, n_part, dtype, device = key.split("|")
    dims = None if d_part == "d-" else tuple(
        int(s) for s in d_part[1:].split("x"))
    k = None if k_part == "k-" else int(k_part[1:])
    n = None if n_part == "n-" else int(n_part[1:])
    return op, dims, k, n, dtype, device


def analysis_targets():
    """Every *tuned* config currently in the active cache, traced at
    its bucket shape so the vmem-budget rule prices the tuned
    BlockSpecs — an autotuned (or hand-pinned) pick that would blow the
    8 MiB budget fails the analysis sweep instead of OOMing on device.
    With an empty cache the untuned defaults are traced instead, so the
    package always contributes the pricing surface."""
    import jax
    import jax.numpy as jnp

    from ..block_topk import block_topk_payload
    from ..hess_update import hess_update
    from ..scatter_accum import scatter_accumulate

    targets = []

    def scatter_target(label, dims, k, n, dtype, tile, chunk):
        v = jax.ShapeDtypeStruct((n, k), jnp.dtype(dtype))
        i = jax.ShapeDtypeStruct((n, k), jnp.int32)
        targets.append({
            "name": f"scatter_accumulate[{label}]",
            "trace": lambda: jax.make_jaxpr(
                lambda vv, ii: scatter_accumulate(
                    vv, ii, dims, use_pallas=True, interpret=True,
                    tile=tile, chunk=chunk or 512))(v, i),
            "context": {},
        })

    entries = get_cache().entries()
    for key in sorted(entries):
        cfg = entries[key]
        op, dims, k, n, dtype, _dev = _parse_key(key)
        label = f"tuned:{key}"
        if op == "scatter_accumulate" and dims and k and n:
            scatter_target(label, dims, k, n, dtype, cfg.tile, cfg.chunk)
        elif op == "hess_update" and dims:
            m = jax.ShapeDtypeStruct(dims, jnp.dtype(dtype))
            block = cfg.block or 128
            targets.append({
                "name": f"hess_update[{label}]",
                "trace": lambda m=m, block=block: jax.make_jaxpr(
                    lambda h, d, s: hess_update(h, d, s, 0.5, block=block,
                                                interpret=True))(m, m, m),
                "context": {"block": block},
            })
        elif op in ("block_topk_payload", "diff_topk_payload") and dims \
                and k and n and cfg.use_pallas:
            # only the Pallas branch has BlockSpecs to price
            x = jax.ShapeDtypeStruct(dims, jnp.dtype(dtype))
            targets.append({
                "name": f"block_topk_payload[{label}]",
                "trace": lambda x=x, k=k, n=n: jax.make_jaxpr(
                    lambda m: block_topk_payload(
                        m, k=k, block=n, use_pallas=True,
                        interpret=True))(x),
                "context": {"block": n},
            })
    if not targets:
        scatter_target("default:single-block,c512", (512, 512), 512, 4,
                       "float32", None, 512)
        scatter_target("default:(512,512),c512", (4096, 4096), 2048, 4,
                       "float32", (512, 512), 512)
    return targets
