"""Winner cache for the kernel autotuner.

A tuned config is keyed on ``(op, d-bucket, k, n, dtype, device kind)``:

  op           the dispatching op name ("scatter_accumulate",
               "block_topk_payload", "diff_topk_payload", "hess_update")
  d-bucket     the output/operand matrix shape with every dim rounded up
               to the next power of two (min 8) — configs generalize
               across nearby problem sizes instead of fragmenting the
               cache per exact d
  k            payload width per silo/tile (None where the op has none)
  n            the op's second problem knob: silo count for the scatter,
               tile block for the top-k family (None where meaningless)
  dtype        canonical numpy dtype name of the values operand
  device kind  ``jax.devices()[0].device_kind`` — a winner measured on
               one generation never silently applies to another

Keys serialize to one flat string, so the persisted JSON cache is a
plain ``{key: config}`` object (plus a schema version) that can be
committed and pinned in CI (``REPRO_TUNING_CACHE=path``). The in-memory
cache is process-global: ops consult it at trace time through
``repro.kernels.tuning.lookup`` and the tuner records winners through
``record``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import threading
from typing import Optional

_SCHEMA = 1

# Env var naming a JSON cache to preload (the CI pin / pre-warm path).
CACHE_ENV = "REPRO_TUNING_CACHE"


@dataclasses.dataclass(frozen=True)
class KernelConfig:
    """One tuned dispatch decision. Fields an op does not tune stay
    None and the op's untuned default applies: ``tile=None`` on the
    scatter means single-block (budget permitting), ``use_pallas=None``
    means backend-default dispatch."""

    tile: Optional[tuple] = None        # (tm, tn) output tile
    chunk: Optional[int] = None         # pair-stream chunk length
    block: Optional[int] = None         # square tile edge (hess_update)
    use_pallas: Optional[bool] = None   # kernel-vs-oracle dispatch

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        if self.tile is not None:
            d["tile"] = list(self.tile)
        return {k: v for k, v in d.items() if v is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "KernelConfig":
        tile = d.get("tile")
        return cls(
            tile=tuple(int(t) for t in tile) if tile is not None else None,
            chunk=int(d["chunk"]) if d.get("chunk") is not None else None,
            block=int(d["block"]) if d.get("block") is not None else None,
            use_pallas=d.get("use_pallas"),
        )


def bucket(x: int) -> int:
    """Next power of two >= x (min 8): the d-bucket dimension."""
    x = max(int(x), 8)
    b = 8
    while b < x:
        b *= 2
    return b


def device_kind() -> str:
    try:
        import jax

        return str(jax.devices()[0].device_kind).replace(" ", "_")
    except Exception:  # noqa: BLE001 — no backend: still a usable key
        return "unknown"


def cache_key(op: str, shape=None, k=None, n=None, dtype=None,
              device: Optional[str] = None) -> str:
    """Deterministic flat key string; see module docstring for fields."""
    if shape is None:
        d_part = "-"
    else:
        d_part = "x".join(str(bucket(s)) for s in shape)
    dt = "-" if dtype is None else str(__import__("numpy").dtype(dtype).name)
    dev = device_kind() if device is None else device
    return "|".join([op, f"d{d_part}",
                     f"k{'-' if k is None else int(k)}",
                     f"n{'-' if n is None else int(n)}", dt, dev])


class TuningCache:
    """Thread-safe key -> KernelConfig store with JSON persistence."""

    def __init__(self, entries: Optional[dict] = None):
        self._lock = threading.Lock()
        self._entries: dict = dict(entries or {})

    def get(self, key: str) -> Optional[KernelConfig]:
        with self._lock:
            return self._entries.get(key)

    def put(self, key: str, cfg: KernelConfig) -> None:
        with self._lock:
            self._entries[key] = cfg

    def entries(self) -> dict:
        with self._lock:
            return dict(self._entries)

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def save(self, path: str) -> None:
        doc = {"schema": _SCHEMA,
               "configs": {k: v.to_dict() for k, v in self.entries().items()}}
        with open(path, "w") as f:
            json.dump(doc, f, indent=2, sort_keys=True)
            f.write("\n")

    @classmethod
    def load(cls, path: str) -> "TuningCache":
        with open(path) as f:
            doc = json.load(f)
        if doc.get("schema") != _SCHEMA:
            raise ValueError(
                f"tuning cache {path!r}: schema {doc.get('schema')!r} != "
                f"{_SCHEMA} — regenerate with the current tuner")
        return cls({k: KernelConfig.from_dict(v)
                    for k, v in doc.get("configs", {}).items()})


_active: Optional[TuningCache] = None
_active_lock = threading.Lock()


def get_cache() -> TuningCache:
    """The process-global cache; first use loads ``$REPRO_TUNING_CACHE``
    when set (the CI pin), else starts empty (untuned defaults rule)."""
    global _active
    with _active_lock:
        if _active is None:
            path = os.environ.get(CACHE_ENV)
            _active = TuningCache.load(path) if path and os.path.exists(path) \
                else TuningCache()
        return _active


def set_cache(cache: Optional[TuningCache]) -> None:
    """Swap the process-global cache (None resets to lazy env load) —
    the test seam and the explicit pre-warm entry point."""
    global _active
    with _active_lock:
        _active = cache


def lookup(op: str, shape=None, k=None, n=None, dtype=None) -> \
        Optional[KernelConfig]:
    """Trace-time dispatch query: the tuned config for this op/problem
    on this device, or None (untuned defaults apply)."""
    return get_cache().get(cache_key(op, shape=shape, k=k, n=n, dtype=dtype))


def record(op: str, cfg: KernelConfig, shape=None, k=None, n=None,
           dtype=None) -> str:
    """Store a winner in the process-global cache; returns its key."""
    key = cache_key(op, shape=shape, k=k, n=n, dtype=dtype)
    get_cache().put(key, cfg)
    return key
