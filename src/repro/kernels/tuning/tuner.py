"""Measurement-driven autotuner for the Pallas dispatch constants.

For each op a candidate list of ``KernelConfig``s is generated (every
candidate's per-program VMEM footprint is checked against the shared
``repro.kernels.VMEM_BUDGET_BYTES`` the same way the ``vmem-budget``
analysis rule prices BlockSpecs — an autotuned pick can never trace
past the budget), pruned to the most promising few by the
``launch/roofline.py`` cost terms (max of compute time at PEAK_FLOPS
and stream time at HBM_BW — the same max(compute, memory) model the
roofline sweep uses), then each survivor is *measured*: median wall
time over a few repetitions with ``block_until_ready``, compile
excluded by a warmup call (the same protocol as ``benchmarks/common``).
The winner is recorded in the process-global ``TuningCache`` (and can
be persisted to JSON with ``cache.save``), after which the ops'
dispatch wrappers pick it up for every *untuned* call with a matching
``(op, d-bucket, k, n, dtype, device kind)`` key.

Tuning happens eagerly (outside jit) — pre-warm the cache before
tracing/jitting the training step, because jit bakes the dispatch
decision at trace time.
"""

from __future__ import annotations

import time
from typing import Callable, Optional, Sequence

import jax
import numpy as np

from .cache import KernelConfig, record

# Candidate pools — the untuned defaults are always included, so the
# tuner can only match or beat the status quo on the measured case.
SCATTER_TILES = ((256, 256), (256, 512), (512, 512), (512, 1024),
                 (1024, 512))
SCATTER_CHUNKS = (256, 512, 1024)
HESS_BLOCKS = (128, 256, 512)

_INDEX_BYTES = 4  # int32 index streams


def _budget() -> int:
    from .. import VMEM_BUDGET_BYTES

    return VMEM_BUDGET_BYTES


def _roofline():
    from ...launch.roofline import HBM_BW, PEAK_FLOPS

    return float(PEAK_FLOPS), float(HBM_BW)


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def time_us(fn: Callable[[], object], reps: int = 3,
            warmup: int = 1) -> float:
    """Median wall microseconds of ``fn()`` (jax outputs synced with
    block_until_ready); ``warmup`` untimed calls absorb compilation."""
    for _ in range(max(warmup, 0)):
        jax.block_until_ready(fn())
    ts = []
    for _ in range(max(reps, 1)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append((time.perf_counter() - t0) * 1e6)
    return float(np.median(ts))


def _measure_winner(candidates: Sequence[KernelConfig],
                    run: Callable[[KernelConfig], object],
                    predict: Optional[Callable[[KernelConfig], float]],
                    max_measured: int, reps: int,
                    timer: Optional[Callable] = None):
    """Prune ``candidates`` by the roofline prediction, measure the
    survivors, return (winner, {config: us}). ``timer`` overrides the
    wall-clock measurement (the deterministic test seam)."""
    cands = list(candidates)
    if not cands:
        raise ValueError("no in-budget candidates to tune over")
    if predict is not None and len(cands) > max_measured:
        cands.sort(key=predict)
        cands = cands[:max_measured]
    timer = timer or (lambda fn: time_us(fn, reps=reps))
    timings = {cfg: float(timer(lambda cfg=cfg: run(cfg))) for cfg in cands}
    winner = min(cands, key=lambda c: timings[c])
    return winner, timings


# -- scatter_accumulate ------------------------------------------------------


def scatter_candidates(shape, k: int, n: int, dtype) -> list:
    """In-budget (tile, chunk) candidates for ``scatter_accumulate`` on
    an (n, k) pair stream into ``shape``. Footprint per program =
    value chunk + index chunk + one output block (exactly what the
    vmem-budget rule sums from the BlockSpecs). ``tile=None`` is the
    single-block kernel, included only while the whole padded
    accumulator fits the budget — matching the untuned dispatch."""
    d0, d1 = (int(s) for s in shape)
    itemsize = np.dtype(dtype).itemsize
    budget = _budget()
    out = []
    acc_bytes = _round_up(d0, 8) * _round_up(d1, 128) * itemsize
    for chunk in SCATTER_CHUNKS:
        ck = min(_round_up(max(k, 1), chunk) if k > chunk else max(k, 1),
                 chunk)
        stream = ck * (itemsize + _INDEX_BYTES)
        if acc_bytes + stream <= budget:
            out.append(KernelConfig(tile=None, chunk=chunk))
        for tile in SCATTER_TILES:
            tm, tn = _round_up(tile[0], 8), _round_up(tile[1], 128)
            if tm > _round_up(d0, 8) and tn > _round_up(d1, 128):
                continue  # bigger than the matrix: alias of single-block
            if tm * tn * itemsize + stream <= budget:
                out.append(KernelConfig(tile=(tm, tn), chunk=chunk))
    return out


def predict_scatter_us(cfg: KernelConfig, shape, k: int, n: int,
                       dtype) -> float:
    """Roofline estimate (us) for one tuned scatter config: every
    (silo, chunk) pair is streamed once per output tile (the tiled
    kernel's compute-for-memory trade), each visit paying two one-hot
    matmuls — max(MXU time, HBM stream time) per the roofline model."""
    peak_flops, hbm_bw = _roofline()
    d0, d1 = (int(s) for s in shape)
    itemsize = np.dtype(dtype).itemsize
    chunk = cfg.chunk or 512
    kp = _round_up(max(k, 1), chunk) if k > chunk else max(k, 1)
    ck = min(kp, chunk)
    nchunks = n * (kp // ck)
    if cfg.tile is None:
        tm, tn = _round_up(d0, 8), _round_up(d1, 128)
    else:
        tm, tn = cfg.tile
    ntiles = _round_up(d0, tm) // tm * (_round_up(d1, tn) // tn)
    flops = 2.0 * ck * tm * tn * nchunks * ntiles      # one-hot matmuls
    bytes_ = (nchunks * ck * (itemsize + _INDEX_BYTES) * ntiles
              + ntiles * tm * tn * itemsize)           # stream replay + out
    return max(flops / peak_flops, bytes_ / hbm_bw) * 1e6


def autotune_scatter_accumulate(values, indices, shape,
                                use_pallas: Optional[bool] = None,
                                interpret: Optional[bool] = None,
                                max_measured: int = 4, reps: int = 3,
                                timer: Optional[Callable] = None,
                                record_winner: bool = True) -> KernelConfig:
    """Measure in-budget (tile, chunk) candidates on this very operand
    and record the winner for the ``(d-bucket, k, n, dtype)`` key."""
    from ..scatter_accum import scatter_accumulate

    n, k = values.shape
    cands = scatter_candidates(shape, k, n, values.dtype)

    def run(cfg: KernelConfig):
        return scatter_accumulate(values, indices, tuple(shape),
                                  use_pallas=use_pallas, interpret=interpret,
                                  tile=cfg.tile, chunk=cfg.chunk)

    winner, _ = _measure_winner(
        cands, run, lambda c: predict_scatter_us(c, shape, k, n,
                                                 values.dtype),
        max_measured, reps, timer)
    if record_winner:
        record("scatter_accumulate", winner, shape=shape, k=k, n=n,
               dtype=values.dtype)
    return winner


# -- hess_update -------------------------------------------------------------


def hess_candidates(shape, dtype) -> list:
    """In-budget square blocks for the fused Hessian update: five
    (block, block) tiles resident per program (h, d, s, out + the error
    cell)."""
    itemsize = np.dtype(dtype).itemsize
    budget = _budget()
    out = []
    for b in HESS_BLOCKS:
        if 4 * b * b * itemsize + itemsize <= budget:
            out.append(KernelConfig(block=b))
    return out


def autotune_hess_update(h, d, s, alpha: float,
                         interpret: Optional[bool] = None,
                         reps: int = 3, timer: Optional[Callable] = None,
                         record_winner: bool = True) -> KernelConfig:
    from ..hess_update import hess_update

    cands = hess_candidates(h.shape, h.dtype)

    def run(cfg: KernelConfig):
        return hess_update(h, d, s, alpha, block=cfg.block,
                           interpret=interpret)

    # memory-bound in every config (the roofline terms are block-
    # independent to first order): measure all, no pruning
    winner, _ = _measure_winner(cands, run, None, len(cands), reps, timer)
    if record_winner:
        record("hess_update", winner, shape=h.shape, dtype=h.dtype)
    return winner


# -- block_topk_payload / diff_topk_payload ----------------------------------


def _topk_candidates() -> list:
    """The top-k family tunes the kernel-vs-oracle dispatch itself: on
    some backends the Pallas body wins, on others the sort-based XLA
    oracle does — measure instead of hardcoding the backend rule."""
    return [KernelConfig(use_pallas=False), KernelConfig(use_pallas=True)]


def autotune_block_topk_payload(x, k: int, block: int = 128,
                                interpret: Optional[bool] = None,
                                reps: int = 3,
                                timer: Optional[Callable] = None,
                                record_winner: bool = True) -> KernelConfig:
    from ..block_topk import block_topk_payload

    def run(cfg: KernelConfig):
        return block_topk_payload(x, k=k, block=block,
                                  use_pallas=cfg.use_pallas,
                                  interpret=interpret)

    winner, _ = _measure_winner(_topk_candidates(), run, None, 2, reps,
                                timer)
    if record_winner:
        record("block_topk_payload", winner, shape=x.shape, k=k, n=block,
               dtype=x.dtype)
    return winner


def autotune_diff_topk_payload(a, b, k: int, block: int = 128,
                               interpret: Optional[bool] = None,
                               reps: int = 3,
                               timer: Optional[Callable] = None,
                               record_winner: bool = True) -> KernelConfig:
    from ..block_topk import diff_topk_payload

    def run(cfg: KernelConfig):
        return diff_topk_payload(a, b, k=k, block=block,
                                 use_pallas=cfg.use_pallas,
                                 interpret=interpret)

    winner, _ = _measure_winner(_topk_candidates(), run, None, 2, reps,
                                timer)
    if record_winner:
        record("diff_topk_payload", winner, shape=a.shape, k=k, n=block,
               dtype=a.dtype)
    return winner
