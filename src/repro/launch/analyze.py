"""``python -m repro.launch.analyze`` — the static-analysis sweep.

Traces every registered Method step, Compressor.aggregate path, Pallas
kernel config, the fednl_precond TPU path, and the full fednl train
step on a reduced real architecture (plus an AST pass over
``src/repro``) and checks the data-path invariants. Trace-only: runs on
CPU CI in seconds, no accelerator needed. Nonzero exit on any
violation — this is the CI gate.

  python -m repro.launch.analyze                  # full sweep
  python -m repro.launch.analyze --list           # enumerate targets
  python -m repro.launch.analyze --rules          # describe the rules
  python -m repro.launch.analyze --rule vmem-budget --target kernel:
  python -m repro.launch.analyze --json report.json
"""

from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.analyze",
        description="static analysis of the traced data paths")
    ap.add_argument("--rule", action="append", dest="rules", metavar="NAME",
                    help="only run this rule (repeatable)")
    ap.add_argument("--target", action="append", dest="targets",
                    metavar="SUBSTR",
                    help="only targets whose name contains SUBSTR "
                         "(repeatable)")
    ap.add_argument("--kind", action="append", dest="kinds",
                    choices=["method-step", "aggregate", "kernel",
                             "precond", "train-step", "source"],
                    help="only targets of this kind (repeatable)")
    ap.add_argument("--json", metavar="PATH",
                    help="also write the JSON report to PATH ('-' for "
                         "stdout)")
    ap.add_argument("--list", action="store_true",
                    help="list targets (with their rules) and exit")
    ap.add_argument("--rules", action="store_true", dest="describe_rules",
                    help="list registered rules and exit")
    ap.add_argument("-v", "--verbose", action="store_true",
                    help="print passing targets too")
    args = ap.parse_args(argv)

    from ..analysis import iter_targets
    from ..analysis.framework import get_rule, rule_descriptions
    from ..analysis.reporters import render_json, render_text
    from ..analysis.targets import analyze

    if args.describe_rules:
        for name, desc in rule_descriptions().items():
            print(f"{name:24s} {desc}")
        return 0

    if args.rules:
        for r in args.rules:
            get_rule(r)  # fail fast on typos

    if args.list:
        for t in iter_targets(args.kinds):
            if args.targets and not any(s in t.name for s in args.targets):
                continue
            print(f"{t.kind:12s} {t.name}  ({', '.join(t.rules)})")
        return 0

    results = analyze(rules=args.rules, targets=args.targets,
                      kinds=args.kinds)
    print(render_text(results, verbose=args.verbose))
    if args.json:
        payload = render_json(results)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as fh:
                fh.write(payload + "\n")
    return 1 if any(v for _, v in results) else 0


if __name__ == "__main__":
    sys.exit(main())
