import os
os.environ["XLA_FLAGS"] = os.environ.get("DRYRUN_XLA_FLAGS",
    "--xla_force_host_platform_device_count=512")
# The two lines above MUST run before any jax import (device count locks
# at first init). Everything else follows.

"""Multi-pod dry-run: lower + compile every (arch x input-shape) pair on
the production mesh, print memory/cost analysis, and emit roofline terms.

Usage:
  python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.jsonl]

Exit code is non-zero if any requested pair fails to compile.
"""

import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_production_mesh
from repro.launch.roofline import Roofline, collective_bytes, model_flops
from repro.launch.shapes import (
    SHAPES,
    decode_input_specs,
    skip_reason,
    token_batch_specs,
)
from repro.launch.sharding import (
    batch_specs,
    cache_specs,
    make_activation_sharder,
    make_layer_param_constrainer,
    tree_param_specs,
)
from repro.launch.steps import (
    make_optimizer,
    make_prefill,
    make_serve_step,
    make_train_step,
)
from repro.models import build_model
from repro.models.common import set_activation_sharder


def _opt_state_shardings(opt_shape, param_shards, mesh):
    """Moment trees mirror the param tree, so the param sharding tree is a
    valid pytree (prefix) for them; scalars replicate."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    rep = NamedSharding(mesh, P())
    out = {}
    for k, v in opt_shape._asdict().items():
        if k == "step":
            out[k] = rep
        elif isinstance(v, tuple) and v == ():
            out[k] = ()
        else:
            out[k] = param_shards
    return type(opt_shape)(**out)


def _lower_one(cfg, shape, mesh, optimizer: str, unroll: bool,
               donate: bool, microbatches: int = 16):
    """Build model + step for (cfg, shape) and return the lowered artifact."""
    model = build_model(cfg, use_remat=True)
    model.unroll = unroll
    params_shape = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
    param_shards = tree_param_specs(params_shape, mesh, cfg)

    if shape.kind == "train":
        opt = make_optimizer(optimizer, 1e-4, moment_dtype=jnp.bfloat16)
        opt_shape = jax.eval_shape(opt.init, params_shape)
        opt_shards = _opt_state_shardings(opt_shape, param_shards, mesh)
        batch = token_batch_specs(cfg, shape)
        b_shards = batch_specs(batch, mesh)
        step = make_train_step(model, opt, microbatches=microbatches)
        jitted = jax.jit(
            step,
            in_shardings=(param_shards, opt_shards, b_shards),
            out_shardings=(param_shards, opt_shards, None),
            donate_argnums=(0, 1) if donate else (),
        )
        return jitted.lower(params_shape, opt_shape, batch)
    if shape.kind == "prefill":
        batch = token_batch_specs(cfg, shape)
        b_shards = batch_specs(batch, mesh)
        fn = make_prefill(model)
        jitted = jax.jit(fn, in_shardings=(param_shards, b_shards))
        return jitted.lower(params_shape, batch)
    # decode
    specs = decode_input_specs(cfg, shape, model)
    c_shards = cache_specs(specs["cache"], mesh, cfg)
    from jax.sharding import NamedSharding, PartitionSpec as P

    tok_shard = batch_specs({"t": specs["token"]}, mesh)["t"]
    pos_shard = NamedSharding(mesh, P())
    fn = make_serve_step(model)
    jitted = jax.jit(
        fn,
        in_shardings=(param_shards, c_shards, tok_shard, pos_shard),
        out_shardings=(None, c_shards),
        donate_argnums=(1,) if donate else (),
    )
    return jitted.lower(params_shape, specs["cache"], specs["token"],
                        specs["pos"])


def _compiled_costs(compiled, chips):
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # older jax: one dict per computation
        cost = cost[0] if cost else {}
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll,
    }


def _probe_costs(cfg, shape, mesh, optimizer: str, model):
    """Exact per-device costs. Scans hide trip counts from cost_analysis
    (loop bodies are counted once), so we either unroll everything (small
    stacks) or extrapolate from 1- and 2-segment unrolled probes:
        total = probe1 + (n_segments - 1) * (probe2 - probe1).
    """
    import dataclasses as dc

    chips = mesh.devices.size
    segs = model.n_segments
    # probes run microbatches=1: a k-microbatch scan hides (k-1)/k of the
    # step's work from cost_analysis, while one full-batch pass does the
    # same total arithmetic as the k accumulated passes.
    if cfg.n_layers <= 8:
        lowered = _lower_one(cfg, shape, mesh, optimizer, unroll=True,
                             donate=False, microbatches=1)
        return _compiled_costs(lowered.compile(), chips), "unrolled"

    enc_per = (cfg.enc_layers // segs) if cfg.enc_layers else 0
    cfg1 = dc.replace(cfg, n_layers=model.period, enc_layers=enc_per)
    cfg2 = dc.replace(cfg, n_layers=2 * model.period, enc_layers=2 * enc_per)
    c1 = _compiled_costs(
        _lower_one(cfg1, shape, mesh, optimizer, unroll=True, donate=False,
                   microbatches=1).compile(), chips)
    c2 = _compiled_costs(
        _lower_one(cfg2, shape, mesh, optimizer, unroll=True, donate=False,
                   microbatches=1).compile(), chips)

    def extrap(a, b):
        return a + (segs - 1) * (b - a)

    out = {
        "flops": extrap(c1["flops"], c2["flops"]),
        "bytes": extrap(c1["bytes"], c2["bytes"]),
        "coll": {k: max(0, int(extrap(c1["coll"][k], c2["coll"][k])))
                 for k in c1["coll"]},
    }
    return out, "probe-extrapolated"


def dryrun_pair(arch: str, shape_name: str, multi_pod: bool = False,
                optimizer: str = "adamw", verbose: bool = True,
                donate: bool = True, with_probes: bool = True,
                mesh=None, smoke: bool = False,
                microbatches: int = 16) -> dict:
    """Lower+compile one pair; returns a result row (raises on failure).
    ``mesh``/``smoke`` let tests run the same path on a tiny host mesh
    with the reduced configs."""
    cfg = get_config(arch, smoke=smoke)
    shape = SHAPES[shape_name]
    reason = skip_reason(cfg, shape)
    if reason:
        return {"arch": arch, "shape": shape_name, "status": "skip",
                "reason": reason}

    if mesh is None:
        mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    set_activation_sharder(make_activation_sharder(mesh),
                           make_layer_param_constrainer(mesh, cfg))
    model = build_model(cfg, use_remat=True)

    t0 = time.time()
    lowered = _lower_one(cfg, shape, mesh, optimizer, unroll=False,
                         donate=donate, microbatches=microbatches)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    if with_probes:
        costs, cost_mode = _probe_costs(cfg, shape, mesh, optimizer, model)
    else:
        costs, cost_mode = _compiled_costs(compiled, chips), "scan-body-once"

    flops = costs["flops"]
    bytes_hbm = costs["bytes"]
    coll = costs["coll"]
    rl = Roofline(flops=flops, bytes_hbm=bytes_hbm, coll=coll, chips=chips,
                  model_flops=model_flops(cfg, shape, shape.kind))

    def _mem_field(name):
        v = getattr(mem, name, None)
        return int(v) if v is not None else None

    row = {
        "arch": arch, "shape": shape_name, "mesh": "2x16x16" if multi_pod
        else "16x16", "status": "ok", "kind": shape.kind,
        "optimizer": optimizer if shape.kind == "train" else None,
        "cost_mode": cost_mode,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "argument_bytes": _mem_field("argument_size_in_bytes"),
        "output_bytes": _mem_field("output_size_in_bytes"),
        "temp_bytes": _mem_field("temp_size_in_bytes"),
        "peak_bytes_per_device": (
            (_mem_field("argument_size_in_bytes") or 0)
            + (_mem_field("temp_size_in_bytes") or 0)),
        **rl.row(),
    }
    if verbose:
        print(f"== {arch} x {shape_name} on {row['mesh']} "
              f"({shape.kind}) ==")
        print(f"  lower {t_lower:.1f}s compile {t_compile:.1f}s")
        print(f"  memory_analysis: args={row['argument_bytes']} "
              f"temp={row['temp_bytes']} out={row['output_bytes']}")
        print(f"  cost_analysis: flops={flops:.3e} bytes={bytes_hbm:.3e}")
        print(f"  collectives: { {k: v for k, v in coll.items() if v} }")
        print(f"  roofline: compute={rl.t_compute:.4f}s memory={rl.t_memory:.4f}s "
              f"collective={rl.t_collective:.4f}s -> {rl.bottleneck}-bound; "
              f"useful_ratio={rl.useful_ratio:.3f}")
        sys.stdout.flush()
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "fednl"])
    ap.add_argument("--out", default=None, help="append JSONL rows here")
    ap.add_argument("--microbatches", type=int, default=16)
    ap.add_argument("--no-probes", action="store_true",
                    help="skip the cost probes (compile-proof only; the "
                         "roofline table is single-pod, so the multi-pod "
                         "pass can run without them)")
    args = ap.parse_args(argv)

    pairs = []
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    for a in archs:
        for s in shapes:
            for mp in meshes:
                pairs.append((a, s, mp))

    failures = 0
    for arch, shape_name, mp in pairs:
        try:
            row = dryrun_pair(arch, shape_name, multi_pod=mp,
                              optimizer=args.optimizer,
                              with_probes=not args.no_probes,
                              microbatches=args.microbatches)
        except Exception as e:  # noqa: BLE001 — report and continue
            traceback.print_exc()
            row = {"arch": arch, "shape": shape_name,
                   "mesh": "2x16x16" if mp else "16x16",
                   "status": "fail", "error": repr(e)[:500]}
            failures += 1
        if args.out:
            with open(args.out, "a") as f:
                f.write(json.dumps(row) + "\n")
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
