"""Production mesh construction.

Kept as functions (never module-level constants) so importing this module
never touches jax device state — required because the dry-run must set
XLA_FLAGS before any jax initialization.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))


def batch_axes(mesh) -> tuple[str, ...]:
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)
