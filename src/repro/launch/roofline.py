"""Roofline terms from a compiled dry-run artifact (TPU v5e targets).

  compute term    = HLO_FLOPs / (chips * 197e12 bf16 FLOP/s)
  memory term     = HLO_bytes / (chips * 819e9 B/s HBM)
  collective term = collective_bytes / (chips * 50e9 B/s per ICI link)

HLO_FLOPs / bytes come from compiled.cost_analysis(); collective bytes
are parsed from the (post-SPMD) HLO text by summing the result-shape
sizes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops. MODEL_FLOPS uses 6·N·D (dense) / 6·N_active·D
(MoE) for training and 2·N(+_active)·D for single forward.
"""

from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # B/s / chip
ICI_BW = 50e9             # B/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum result-shape bytes per collective kind (start/done pairs counted
    once via the '-start' form; plain forms counted directly)."""
    out = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        s = line.strip()
        if "=" not in s:
            continue
        lhs, rhs = s.split("=", 1)
        rhs = rhs.strip()
        m = re.match(r"^(\([^)]*\)|[a-z0-9_]+\[[0-9,]*\][^ ]*)\s+([a-z0-9-]+)", rhs)
        if not m:
            continue
        shapes_txt, op = m.group(1), m.group(2)
        base = op.replace("-start", "")
        if base in _COLLECTIVES and not op.endswith("-done"):
            out[base] += _shape_bytes(shapes_txt)
    return out


@dataclasses.dataclass
class Roofline:
    """All HLO-derived quantities are PER-DEVICE (cost_analysis reports the
    local SPMD executable — verified against a hand-sharded matmul);
    model_flops is the GLOBAL analytic count."""

    flops: float
    bytes_hbm: float
    coll: dict[str, int]
    chips: int
    model_flops: float = 0.0

    @property
    def coll_bytes(self) -> int:
        return sum(self.coll.values())

    @property
    def t_compute(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_hbm / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS, both normalized per device."""
        return (self.model_flops / self.chips) / self.flops if self.flops else 0.0

    def row(self) -> dict:
        return {
            "flops": self.flops, "bytes": self.bytes_hbm,
            "coll_bytes": self.coll_bytes,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_ratio": self.useful_ratio,
            **{f"bytes_{k}": v for k, v in self.coll.items()},
        }


def count_params(cfg, active_only: bool = False) -> float:
    """Analytic parameter count (embeddings included once)."""
    d, ff, v, L = cfg.d_model, cfg.d_ff, cfg.vocab, cfg.n_layers
    hd = cfg.hd
    total = v * d * (1 if cfg.tie_embeddings else 2)

    def attn_params():
        if cfg.attn_type == "mla":
            m = cfg.mla
            qd = m.qk_nope_dim + m.qk_rope_dim
            return (d * m.q_lora_rank + m.q_lora_rank * cfg.n_heads * qd
                    + d * m.kv_lora_rank + d * m.qk_rope_dim
                    + m.kv_lora_rank * cfg.n_heads * (m.qk_nope_dim + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
        return d * cfg.n_heads * hd + 2 * d * cfg.kv_heads * hd \
            + cfg.n_heads * hd * d

    def mlp_params(experts: int = 1, topk: int = 1, active: bool = False):
        per = (3 if cfg.mlp_type == "swiglu" else 2) * d * ff
        e = (topk if active else experts)
        return per * e

    from repro.models.transformer import layer_kinds

    for i, (mixer, ffn) in enumerate(layer_kinds(cfg)):
        if mixer in ("attn", "mla"):
            total += attn_params()
        elif mixer == "mamba":
            di = cfg.mamba.expand * d
            total += d * 2 * di + cfg.mamba.d_conv * di \
                + di * 2 * cfg.mamba.d_state + di + di * cfg.mamba.d_state + di * d
        elif mixer == "mlstm":
            total += 5 * d * d + d * 2 * cfg.n_heads
        elif mixer == "slstm":
            total += 9 * d * d
        if ffn == "moe":
            total += mlp_params(cfg.moe.num_experts, cfg.moe.top_k,
                                active=active_only) + d * cfg.moe.num_experts
        elif ffn == "mlp":
            total += mlp_params()
    if cfg.family == "encdec":
        for _ in range(cfg.enc_layers):
            total += attn_params() * 2 + mlp_params()  # self + cross (in dec)
    return float(total)


def model_flops(cfg, shape, kind: str) -> float:
    """6·N_active·tokens for training; 2·N_active·tokens for fwd/decode."""
    n_active = count_params(cfg, active_only=True)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens
