"""Serving driver: batched prefill + decode loop with a KV/state cache.

  PYTHONPATH=src python -m repro.launch.serve --arch xlstm-350m --smoke \
      --batch 4 --prompt-len 32 --gen 16
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import make_activation_sharder, make_layer_param_constrainer
from repro.launch.steps import make_serve_step
from repro.models import build_model
from repro.models.common import set_activation_sharder


def generate(arch: str, smoke: bool = True, batch: int = 4,
             prompt_len: int = 16, gen: int = 16, seed: int = 0,
             temperature: float = 1.0, greedy: bool = False):
    cfg = get_config(arch, smoke=smoke)
    mesh = make_host_mesh()
    set_activation_sharder(make_activation_sharder(mesh),
                           make_layer_param_constrainer(mesh, cfg))
    model = build_model(cfg, use_remat=False)
    params = model.init_params(jax.random.PRNGKey(seed))
    serve = jax.jit(make_serve_step(model))

    key = jax.random.PRNGKey(seed + 1)
    prompt = jax.random.randint(key, (batch, prompt_len), 0, cfg.vocab)
    max_len = prompt_len + gen
    cache = model.init_cache(batch, max_len)
    if cfg.family == "encdec":
        cache["enc"] = jax.random.normal(
            key, (batch, cfg.enc_seq, cfg.d_model), cfg.jdtype) * 0.02

    # prefill token-by-token through the serve path (exercises the cache
    # exactly as production decode does; a fused prefill is the fast path)
    toks = prompt
    logits = None
    for pos in range(prompt_len):
        logits, cache = serve(params, cache, toks[:, pos:pos + 1],
                              jnp.asarray(pos, jnp.int32))

    out = [toks]
    t0 = time.time()
    for i in range(gen):
        key, sub = jax.random.split(key)
        if greedy:
            nxt = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        else:
            nxt = jax.random.categorical(
                sub, logits[:, -1] / temperature, axis=-1)[:, None]
        out.append(nxt)
        logits, cache = serve(params, cache, nxt,
                              jnp.asarray(prompt_len + i, jnp.int32))
    dt = time.time() - t0
    seqs = jnp.concatenate(out, axis=1)
    print(f"generated {gen} tokens x {batch} seqs in {dt:.2f}s "
          f"({batch * gen / dt:.1f} tok/s)")
    return seqs


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()
    seqs = generate(args.arch, smoke=args.smoke, batch=args.batch,
                    prompt_len=args.prompt_len, gen=args.gen)
    print("sample token ids:", seqs[0, : args.prompt_len + 8].tolist())


if __name__ == "__main__":
    main()
