"""Assigned input shapes and ShapeDtypeStruct stand-ins (no allocation).

  train_4k       seq 4096,    global_batch 256   (train_step)
  prefill_32k    seq 32768,   global_batch 32    (prefill forward)
  decode_32k     seq 32768,   global_batch 128   (serve_step, 1 token)
  long_500k      seq 524288,  global_batch 1     (serve_step, 1 token)

For VLM the text length is seq_len - vision_tokens so the total sequence
matches the assigned shape; for audio (whisper) the encoder consumes the
stubbed (B, enc_seq, d) frame embeddings and the decoder runs the
assigned sequence.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def token_batch_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStructs for a train/prefill batch."""
    b, t = shape.global_batch, shape.seq_len
    batch = {}
    t_text = t
    if cfg.family == "vlm":
        t_text = t - cfg.vision_tokens
        batch["patches"] = sds((b, cfg.vision_tokens, cfg.d_model), cfg.jdtype)
    if cfg.family == "encdec":
        batch["frames"] = sds((b, cfg.enc_seq, cfg.d_model), cfg.jdtype)
    batch["tokens"] = sds((b, t_text), jnp.int32)
    batch["targets"] = sds((b, t_text), jnp.int32)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: InputShape, model) -> dict:
    """ShapeDtypeStructs for serve_step: cache of seq_len + one token."""
    b, t = shape.global_batch, shape.seq_len
    cache_shape = jax.eval_shape(lambda: model.init_cache(b, t))
    return {
        "cache": cache_shape,
        "token": sds((b, 1), jnp.int32),
        "pos": sds((), jnp.int32),
    }


def skip_reason(cfg: ModelConfig, shape: InputShape) -> str | None:
    """Why an (arch, shape) pair is skipped, or None if it runs."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        if cfg.family == "encdec":
            return ("whisper decoder max context is 448 by construction; a "
                    "524k full-attention self-attn cache is architecturally "
                    "meaningless (DESIGN.md §4)")
        return ("pure full-attention stack without sliding-window/block-"
                "sparse variant; long_500k requires sub-quadratic attention "
                "(DESIGN.md §4)")
    return None
