"""Sharding rules: parameter-path -> PartitionSpec, activation hints,
cache specs. All rules degrade gracefully: an axis is only used when the
dimension is divisible by its mesh extent (GQA head counts like 14 or 24
don't divide 16; those dims fall back to replication on that axis).

Layout (see DESIGN.md §5):
  * batch over ("pod", "data")
  * attention heads / ffn hidden / vocab over "model"
  * FSDP-style second factor: the non-"model" weight dim over ("pod","data")
  * MoE experts over "model" when divisible (expert parallel), otherwise
    the expert ffn dim goes to "model" (tensor parallel within expert)
"""

from __future__ import annotations

from typing import Any

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

from .mesh import batch_axes


def _ax(mesh: Mesh, name) -> int:
    if isinstance(name, tuple):
        out = 1
        for n in name:
            out *= _ax(mesh, n)
        return out
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def _fits(dim: int, mesh: Mesh, name) -> bool:
    return dim % _ax(mesh, name) == 0


def _spec(mesh: Mesh, shape, wants) -> P:
    """wants: per-dim axis name (or tuple or None); drop non-divisible."""
    out = []
    for dim, w in zip(shape, wants):
        if w is None:
            out.append(None)
        elif _fits(dim, mesh, w):
            out.append(w)
        else:
            # try a prefix of a tuple request, e.g. ("pod","data") -> "data"
            if isinstance(w, tuple):
                picked = None
                for sub in w:
                    if _fits(dim, mesh, sub):
                        picked = sub
                        break
                out.append(picked)
            else:
                out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------


def param_spec(path: str, shape, mesh: Mesh, cfg: ModelConfig) -> P:
    """``path`` is a '/'-joined key path; ``shape`` excludes nothing (the
    stacked segment axis, if present, is dim 0 and is detected by name)."""
    ba = batch_axes(mesh)
    name = path.split("/")[-1]
    stacked = "layers" in path or "enc_layers" in path
    body = shape[1:] if stacked else shape
    lead = (None,) if stacked else ()

    def done(wants):
        return _spec(mesh, shape, lead + tuple(wants))

    # --- embeddings & head ---------------------------------------------------
    if name in ("embed", "lm_head"):
        return _spec(mesh, shape, ("model", ba))

    # --- norms / scalars / biases ---------------------------------------------
    if len(body) <= 1:
        if name in ("bq", "bk", "bv") and len(body) == 1:
            return done(["model"])
        return done([None] * len(body))

    # --- MoE (E, din, dout) ----------------------------------------------------
    if len(body) == 3 and name in ("wi", "wg", "wo"):
        e = body[0]
        if _fits(e, mesh, "model"):
            return done(["model", ba, None])
        # E doesn't divide the model axis: tensor parallelism inside each
        # expert, FSDP on the other dim. NB (§Perf iteration 3, REFUTED):
        # moving the FSDP factor onto the contraction dims of both expert
        # einsums ("wo": (None, ba, "model")) to avoid the output-axis
        # conflict DOUBLED collective traffic (63.6 s -> 133.8 s on
        # grok-1 train_4k) — GSPMD's resharding of the conflicted output
        # is cheaper than explicit gathers of TP'd expert weights here.
        if name == "wo":
            return done([None, "model", ba])
        return done([None, ba, "model"])
    if name == "router":
        return done([None, None])

    # --- projections (din, dout) -------------------------------------------------
    if len(body) == 2:
        reduce_in = name in ("wo", "wout", "wuk", "wuv")
        # MLA down-projections keep latent replicated
        if name in ("wdq", "wdkv", "wkrope"):
            return done([ba, None])
        if name in ("wuq",):
            return done([None, "model"])
        if reduce_in:
            return done(["model", ba])
        return done([ba, "model"])

    # conv kernels etc.
    return done([None] * len(body))


def tree_param_specs(params_shape: Any, mesh: Mesh, cfg: ModelConfig):
    """Map a pytree of ShapeDtypeStructs/arrays to NamedShardings."""

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + "/" + k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            t = [walk(v, prefix + f"/{i}") for i, v in enumerate(tree)]
            return type(tree)(t) if not isinstance(tree, tuple) else tuple(t)
        spec = param_spec(prefix, tree.shape, mesh, cfg)
        return NamedSharding(mesh, spec)

    return walk(params_shape, "params")


def make_layer_param_constrainer(mesh: Mesh, cfg: ModelConfig):
    """Constraint for the per-layer param slice INSIDE a scan body (same
    name-based rules, no stacked leading axis). Keeps the FSDP all-gather
    per-layer instead of letting XLA hoist a whole-stack gather."""

    def constrain(tree):
        def walk(t, prefix):
            if isinstance(t, dict):
                return {k: walk(v, prefix + "/" + k) for k, v in t.items()}
            if isinstance(t, (list, tuple)):
                out = [walk(v, prefix + f"/{i}") for i, v in enumerate(t)]
                return tuple(out) if isinstance(t, tuple) else out
            spec = param_spec(prefix, t.shape, mesh, cfg)
            return jax.lax.with_sharding_constraint(t, NamedSharding(mesh, spec))

        return walk(tree, "inloop")

    return constrain


def opt_state_shardings(state_shape: Any, params: Any, mesh: Mesh,
                        cfg: ModelConfig):
    """NamedShardings for an optimizer-state pytree (``jax.eval_shape``
    of ``opt.init``): every state field that mirrors the params tree —
    Adam moments, fednl's diagonal curvature H and its momentum — gets
    the params' own ``param_spec`` shardings, so second-order state
    scales with the param shards and never concentrates on one chip's
    HBM. Fields with any other structure (step counters, the per-tensor
    scalar ridge ``l``, empty ``()`` slots) are replicated."""
    pspecs = tree_param_specs(params, mesh, cfg)
    pdef = jax.tree.structure(params)
    pshapes = [p.shape for p in jax.tree.leaves(params)]
    rep = NamedSharding(mesh, P())

    def field(sub):
        try:
            mirrors = (jax.tree.structure(sub) == pdef and
                       [x.shape for x in jax.tree.leaves(sub)] == pshapes)
        except Exception:
            mirrors = False
        if mirrors:
            return pspecs
        return jax.tree.map(lambda _: rep, sub)

    if hasattr(state_shape, "_fields"):  # NamedTuple states
        return type(state_shape)(*[field(f) for f in state_shape])
    return field(state_shape)


# ---------------------------------------------------------------------------
# Activation hints (installed via models.common.set_activation_sharder)
# ---------------------------------------------------------------------------


def make_activation_sharder(mesh: Mesh):
    ba = batch_axes(mesh)

    def shard(x, kind: str):
        if kind == "btd":
            spec = _spec(mesh, x.shape, (ba,) + (None,) * (x.ndim - 1))
        elif kind == "btf":
            spec = _spec(mesh, x.shape, (ba,) + (None,) * (x.ndim - 2) + ("model",))
        elif kind == "bthd":
            spec = _spec(mesh, x.shape, (ba, None, "model", None))
        elif kind == "logits":
            spec = _spec(mesh, x.shape, (ba,) + (None,) * (x.ndim - 2) + ("model",))
        elif kind == "ecf":
            # MoE expert intermediates (NG, E, C, d_or_ff): groups follow the
            # batch axes; experts over "model" when divisible (expert
            # parallel), else the hidden dim over "model" (TP inside expert).
            if _fits(x.shape[1], mesh, "model"):
                wants = (ba, "model") + (None,) * (x.ndim - 2)
            else:
                wants = (ba,) + (None,) * (x.ndim - 2) + ("model",)
            spec = _spec(mesh, x.shape, wants)
        elif kind == "moe_route":
            # routing tensors (NG, ...): groups over the batch axes only
            spec = _spec(mesh, x.shape, (ba,) + (None,) * (x.ndim - 1))
        elif kind == "carry":
            # sequence parallelism at segment boundaries: the scan-carried
            # residual (B, T, d) shards T over "model", so the remat stash
            # (n_segments x carry) is 16x smaller per chip; attention/scan
            # mixers re-gather T inside the layer, MLPs stay seq-sharded.
            spec = _spec(mesh, x.shape, (ba, "model", None))
        else:
            return x
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return shard


# ---------------------------------------------------------------------------
# Server accumulator placement
# ---------------------------------------------------------------------------


def accumulator_spec(mesh: Mesh, shape, axis: str = "data") -> NamedSharding:
    """Placement of the server's dense (d0, d1) aggregation accumulator:
    row-sharded over ``mesh[axis]`` — the layout
    ``sharded_scatter_accumulate`` (kernels/scatter_accum/sharded.py)
    produces, each device owning a contiguous row window. Degrades to
    replication when d0 doesn't divide the axis extent, like every other
    rule here (the sharded scatter itself then refuses; callers fall
    back to the streamed single-device path)."""
    return NamedSharding(mesh, _spec(mesh, shape, (axis, None)))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------


def batch_specs(batch_shape: Any, mesh: Mesh):
    ba = batch_axes(mesh)

    def one(x):
        spec = _spec(mesh, x.shape, (ba,) + (None,) * (x.ndim - 1))
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, batch_shape)


def cache_specs(cache_shape: Any, mesh: Mesh, cfg: ModelConfig):
    """KV caches: batch over ("pod","data"); kv-head dim over "model" when
    divisible, else sequence dim over "model" (sequence-parallel cache),
    else replicated. SSM states: feature dim over "model"."""
    ba = batch_axes(mesh)

    def walk(tree, prefix):
        if isinstance(tree, dict):
            return {k: walk(v, prefix + "/" + k) for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return type(tree)(walk(v, prefix + f"/{i}") for i, v in enumerate(tree))
        shape = tree.shape
        name = prefix.split("/")[-1]
        # layouts by leaf name
        if name in ("k", "v"):           # (seg, B, S, KV, hd)
            wants = (None, ba, None, "model", None)
            if not _fits(shape[3], mesh, "model") and _fits(shape[2], mesh, "model"):
                wants = (None, ba, "model", None, None)
            return NamedSharding(mesh, _spec(mesh, shape, wants))
        if name in ("ckv", "krope"):     # (seg, B, S, r)
            wants = (None, ba, "model" if _fits(shape[2], mesh, "model") else None, None)
            return NamedSharding(mesh, _spec(mesh, shape, wants))
        if name == "conv":               # (seg, B, k, Di)
            return NamedSharding(mesh, _spec(mesh, shape, (None, ba, None, "model")))
        if name == "ssm":                # (seg, B, Di, S)
            return NamedSharding(mesh, _spec(mesh, shape, (None, ba, "model", None)))
        if name == "c" and len(shape) == 5:  # mlstm (seg, B, H, hd, hd)
            return NamedSharding(mesh, _spec(mesh, shape, (None, ba, "model", None, None)))
        if name in ("c", "n", "m", "h"):
            wants = (None, ba) + (None,) * (len(shape) - 2)
            return NamedSharding(mesh, _spec(mesh, shape, wants))
        if name == "enc":                # (B, S_enc, d)
            return NamedSharding(mesh, _spec(mesh, shape, (ba, None, None)))
        return NamedSharding(mesh, _spec(mesh, shape, (None,) * len(shape)))

    return walk(cache_shape, "cache")
