"""Jittable train/prefill/serve steps shared by the trainer, the server,
and the dry-run.

``make_train_step``  : (params, opt_state, batch) -> (params, opt_state, metrics)
``make_prefill``     : (params, batch) -> logits
``make_serve_step``  : (params, cache, token, pos) -> (logits, cache)

Optimizer choice: 'adamw' | 'sgd' | 'fednl' (the paper's technique as a
structured-curvature preconditioner — see second_order/fednl_precond.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.second_order import adamw, sgd
from repro.second_order.fednl_precond import FedNLPrecondOptimizer
from repro.second_order.optim import apply_updates


def make_optimizer(name: str, lr: float, moment_dtype=None, **kw):
    if name == "adamw":
        return adamw(lr, moment_dtype=moment_dtype)
    if name == "sgd":
        return sgd(lr, momentum=0.9)
    if name == "fednl":
        opt = FedNLPrecondOptimizer(lr=lr, **kw)
        from repro.second_order.optim import Optimizer

        # bind update directly: the optional observations 4th arg (the
        # cross-silo payload path) must survive the adapter
        return Optimizer(opt.init, opt.update)
    raise ValueError(name)


def make_train_step(model: Model, optimizer, microbatches: int = 1,
                    unroll_microbatches: bool = False):
    """``microbatches > 1`` splits the global batch and accumulates grads
    with an inner scan — the remat residual stash then holds one
    microbatch's activations instead of the whole batch's (the difference
    between 51 GB and 6 GB per chip for grok-1 at train_4k).
    ``unroll_microbatches`` unrolls that scan so cost_analysis counts
    every microbatch (dry-run probes only)."""

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb_batch):
                loss_acc, g_acc = carry
                loss_i, g_i = grads_of(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_i)
                return (loss_acc + loss_i, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), mb,
                unroll=microbatches if unroll_microbatches else 1)
            loss = loss / microbatches
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), grads, params)

        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        # NB: reduce per-leaf WITHOUT reshaping — flattening a 2D-sharded
        # tensor forces GSPMD to all-gather it (412 GB for grok-1's
        # stacked expert grads); jnp.sum over all axes partitions cleanly.
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step


def make_prefill(model: Model):
    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def make_serve_step(model: Model):
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step
