"""Jittable train/prefill/serve steps shared by the trainer, the server,
and the dry-run.

``make_train_step``  : (params, opt_state, batch) -> (params, opt_state, metrics)
``make_prefill``     : (params, batch) -> logits
``make_serve_step``  : (params, cache, token, pos) -> (logits, cache)

Optimizer choice: 'adamw' | 'sgd' | 'fednl' (the paper's technique as a
structured-curvature preconditioner — see second_order/fednl_precond.py).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import Model
from repro.second_order import adamw, fednl_precond, sgd
from repro.second_order.optim import apply_updates


def make_optimizer(name: str, lr: float, moment_dtype=None, **kw):
    if name == "adamw":
        return adamw(lr, moment_dtype=moment_dtype)
    if name == "sgd":
        return sgd(lr, momentum=0.9)
    if name == "fednl":
        # the adapter binds update directly (the observations 4th arg —
        # the cross-silo payload path — must survive) AND the amortized
        # observe/refresh/precondition protocol that make_train_step's
        # curvature phase drives.
        return fednl_precond(lr, **kw)
    raise ValueError(name)


def make_train_step(model: Model, optimizer, microbatches: int = 1,
                    unroll_microbatches: bool = False,
                    refresh_every: int = 1, n_silos: int = 1,
                    hvp: bool = False, probe_seed: int = 0):
    """``microbatches > 1`` splits the global batch and accumulates grads
    with an inner scan — the remat residual stash then holds one
    microbatch's activations instead of the whole batch's (the difference
    between 51 GB and 6 GB per chip for grok-1 at train_4k).
    ``unroll_microbatches`` unrolls that scan so cost_analysis counts
    every microbatch (dry-run probes only).

    Second-order optimizers (``optimizer.refresh`` is set — the fednl
    path) get a curvature-observation phase: every ``refresh_every``
    steps (a jittable ``lax.cond`` on the step counter, so the interval
    costs nothing to the compiled graph on the other steps) the global
    batch is split along its leading axis into ``n_silos`` shards — the
    mesh data axis in the launch driver, so each data shard plays one
    FedNL silo — and an inner scan computes one curvature observation
    per silo (empirical-Fisher g^2, or a Hutchinson z*(Hz) probe via
    one jvp-of-grad when ``hvp``). The silo-stacked observations flow
    through ``optimizer.refresh`` (per-silo fused diff payloads +
    payload-space server mean — the paper's uplink placement) and the
    actual parameter update is ``optimizer.precondition`` from the
    stored curvature: refresh cost is amortized, the per-step cost is
    an elementwise diagonal solve. First-order optimizers ignore all
    of this and take the plain ``update`` path."""

    second_order = getattr(optimizer, "refresh", None) is not None \
        and refresh_every >= 1

    def grads_of(params, batch):
        return jax.value_and_grad(model.loss_fn)(params, batch)

    def observe_and_refresh(state, params, batch):
        """One curvature refresh: scan over the silo shards of the
        batch, one observation each, then learn H from the stack."""
        sb = jax.tree.map(
            lambda x: x.reshape((n_silos, x.shape[0] // n_silos)
                                + x.shape[1:]), batch)

        def silo_obs(carry, xs):
            b_i, i = xs
            if hvp:
                # forward-over-reverse: primal out is the silo grad,
                # tangent out is Hz — one pass buys both.
                key = jax.random.fold_in(
                    jax.random.fold_in(jax.random.PRNGKey(probe_seed),
                                       state.step), i)
                leaves, treedef = jax.tree_util.tree_flatten(params)
                keys = jax.random.split(key, len(leaves))
                z = treedef.unflatten([
                    jax.random.rademacher(k, p.shape, jnp.int8
                                          ).astype(p.dtype)
                    for k, p in zip(keys, leaves)])
                gfn = lambda p: jax.grad(model.loss_fn)(p, b_i)
                g_i, hz = jax.jvp(gfn, (params,), (z,))
                obs = optimizer.observe(g_i, params, hvp=(z, hz))
            else:
                g_i = jax.grad(model.loss_fn)(params, b_i)
                obs = optimizer.observe(g_i)
            return carry, obs

        _, obs = jax.lax.scan(silo_obs, 0,
                              (sb, jnp.arange(n_silos, dtype=jnp.int32)))
        return optimizer.refresh(state, obs)

    def train_step(params, opt_state, batch):
        if microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape((microbatches, x.shape[0] // microbatches)
                                    + x.shape[1:]), batch)

            def acc_body(carry, mb_batch):
                loss_acc, g_acc = carry
                loss_i, g_i = grads_of(params, mb_batch)
                g_acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), g_acc, g_i)
                return (loss_acc + loss_i, g_acc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), mb,
                unroll=microbatches if unroll_microbatches else 1)
            loss = loss / microbatches
            grads = jax.tree.map(
                lambda g, p: (g / microbatches).astype(p.dtype), grads, params)

        refreshed = jnp.zeros((), jnp.float32)
        if second_order:
            b0 = jax.tree.leaves(batch)[0].shape[0]
            if b0 % n_silos:
                raise ValueError(
                    f"global batch {b0} must divide into n_silos={n_silos}")
            do_refresh = (opt_state.step % refresh_every) == 0
            opt_state = jax.lax.cond(
                do_refresh,
                lambda s: observe_and_refresh(s, params, batch),
                lambda s: s, opt_state)
            refreshed = do_refresh.astype(jnp.float32)
            updates, opt_state = optimizer.precondition(
                grads, opt_state, params)
        else:
            updates, opt_state = optimizer.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        # NB: reduce per-leaf WITHOUT reshaping — flattening a 2D-sharded
        # tensor forces GSPMD to all-gather it (412 GB for grok-1's
        # stacked expert grads); jnp.sum over all axes partitions cleanly.
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "curv_refreshed": refreshed}

    return train_step


def make_prefill(model: Model):
    def prefill(params, batch):
        logits, _ = model.forward(params, batch)
        return logits

    return prefill


def make_serve_step(model: Model):
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, cache, token, pos)

    return serve_step
