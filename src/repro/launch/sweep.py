"""CLI front-end for the experiment engine: run a method x level x seed
grid on a named problem from the command line, optionally sharded over
the host mesh, and print tidy records (or a per-cell summary) as CSV —
records carry the analytic ``bits``, the payload-measured
``bits_measured``, the entropy-index-coded ``bits_entropy``, and the
traffic-model ``seconds_per_round`` (``--link`` preset) columns side
by side.

    PYTHONPATH=src python -m repro.launch.sweep \
        --problem a1a --method fednl --compressor rankr --levels 1,2,4 \
        --seeds 0,1,2 --rounds 40 --option 1 --mu 1e-3 --target 1e-12

    # whole-grid sharded execution over the data axis
    PYTHONPATH=src python -m repro.launch.sweep --problem a1a \
        --method fednl --compressor rankr --levels 1 --sharded
"""

from __future__ import annotations

import argparse
import sys


def _parse_list(s: str, cast=float):
    return [cast(x) for x in s.split(",") if x != ""]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--problem", default="a1a",
                    help="a1a | phishing | ... | synthetic:ALPHA:BETA")
    ap.add_argument("--method", default="fednl")
    ap.add_argument("--compressor", default="rankr")
    ap.add_argument("--levels", default="1")
    ap.add_argument("--seeds", default="0")
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--alpha", type=float, default=None,
                    help="Hessian learning rate (omit for the method default;"
                         " not every method takes one)")
    ap.add_argument("--option", type=int, default=None)
    ap.add_argument("--mu", type=float, default=0.0)
    ap.add_argument("--tau", type=int, default=None)
    ap.add_argument("--lam", type=float, default=1e-3)
    ap.add_argument("--x64", action=argparse.BooleanOptionalAction,
                    default=True, help="run in float64 (--no-x64 for f32)")
    ap.add_argument("--target", type=float, default=None,
                    help="emit per-cell summary with bits/rounds to target")
    ap.add_argument("--records", action="store_true",
                    help="emit full (cell, seed, round) tidy records")
    ap.add_argument("--sharded", action="store_true",
                    help="run through the shard_map path over the host mesh")
    ap.add_argument("--link", default="wan",
                    help="traffic-model link preset for the "
                         "seconds_per_round column (datacenter | wan | "
                         "fl-cross-device | none)")
    args = ap.parse_args(argv)

    import jax

    if args.x64:
        jax.config.update("jax_enable_x64", True)

    from ..data.problems import make_problem
    from ..engine import ExperimentSpec, Sweep

    params = {}
    if args.alpha is not None:
        params["alpha"] = args.alpha
    if args.option is not None:
        params["option"] = args.option
    if args.mu:
        params["mu"] = args.mu
    if args.tau is not None:
        params["tau"] = args.tau

    prob = make_problem(args.problem, args.lam, seed=0)
    seeds = tuple(int(s) for s in _parse_list(args.seeds, int))
    specs = [
        ExperimentSpec(args.method, args.compressor, lvl, params=params,
                       seeds=seeds, num_rounds=args.rounds)
        for lvl in _parse_list(args.levels)
    ]
    mesh = None
    if args.sharded:
        mesh = jax.make_mesh((jax.device_count(),), ("data",))

    x0 = prob["xstar"] + 0.05 * jax.random.normal(
        jax.random.PRNGKey(1), (prob["d"],))
    link = None if args.link in ("none", "") else args.link
    res = Sweep(specs, mesh=mesh, link=link).run(prob, x0=x0)

    rows = (res.records() if args.records
            else res.summary(target=args.target))
    if not rows:
        return 0
    cols = list(rows[0])
    print(",".join(cols))
    for r in rows:
        print(",".join(str(r[c]) for c in cols))
    return 0


if __name__ == "__main__":
    sys.exit(main())
