"""Training driver: runs real steps on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --optimizer fednl

On the CPU container this trains the reduced (smoke) configs; pointed at
a TPU slice the same code paths run the full configs on the production
mesh (the dry-run proves those lower+compile).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import save as save_ckpt
from repro.configs import ARCHS, get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import make_activation_sharder, make_layer_param_constrainer
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import build_model
from repro.models.common import set_activation_sharder


def add_modality_inputs(batch, cfg, step: int):
    key = jax.random.fold_in(jax.random.PRNGKey(1234), step)
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), cfg.jdtype) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), cfg.jdtype) * 0.02
    return batch


def train(arch: str, smoke: bool = True, steps: int = 20, batch: int = 8,
          seq: int = 128, lr: float = 3e-4, optimizer: str = "adamw",
          microbatches: int = 1, log_every: int = 10, ckpt: str | None = None,
          seed: int = 0):
    cfg = get_config(arch, smoke=smoke)
    mesh = make_host_mesh()
    set_activation_sharder(make_activation_sharder(mesh),
                           make_layer_param_constrainer(mesh, cfg))
    model = build_model(cfg, use_remat=True)
    params = model.init_params(jax.random.PRNGKey(seed))
    opt = make_optimizer(optimizer, lr)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=microbatches))

    t_text = seq - (cfg.vision_tokens if cfg.family == "vlm" else 0)
    pipe = TokenPipeline(vocab_size=cfg.vocab, seq_len=t_text,
                         global_batch=batch, seed=seed)
    history = []
    t0 = time.time()
    for i in range(steps):
        b = add_modality_inputs(pipe.batch(i), cfg, i)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        history.append(float(metrics["loss"]))
        if i % log_every == 0 or i == steps - 1:
            print(f"step {i:5d} loss {history[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.time()-t0):.1f}s)", flush=True)
    if ckpt:
        save_ckpt(ckpt, {"params": params}, step=steps)
        print(f"checkpoint -> {ckpt}")
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "fednl"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, optimizer=args.optimizer,
          microbatches=args.microbatches, ckpt=args.ckpt)


if __name__ == "__main__":
    main()
