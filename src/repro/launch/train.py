"""Training driver: runs real steps on whatever devices exist.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
      --steps 50 --optimizer fednl

On the CPU container this trains the reduced (smoke) configs; pointed at
a TPU slice the same code paths run the full configs on the production
mesh (the dry-run proves those lower+compile).
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint import save as save_ckpt
from repro.configs import ARCHS, get_config
from repro.data.tokens import TokenPipeline
from repro.launch.mesh import make_host_mesh
from repro.launch.sharding import (
    make_activation_sharder,
    make_layer_param_constrainer,
    opt_state_shardings,
    tree_param_specs,
)
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import build_model
from repro.models.common import set_activation_sharder


def add_modality_inputs(batch, cfg, step: int):
    key = jax.random.fold_in(jax.random.PRNGKey(1234), step)
    b = batch["tokens"].shape[0]
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model), cfg.jdtype) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model), cfg.jdtype) * 0.02
    return batch


def train(arch: str, smoke: bool = True, steps: int = 20, batch: int = 8,
          seq: int = 128, lr: float = 3e-4, optimizer: str = "adamw",
          microbatches: int = 1, log_every: int = 10, ckpt: str | None = None,
          seed: int = 0, refresh_every: int = 4, curvature_k: int = 2048,
          hvp: bool = False):
    cfg = get_config(arch, smoke=smoke)
    mesh = make_host_mesh()
    set_activation_sharder(make_activation_sharder(mesh),
                           make_layer_param_constrainer(mesh, cfg))
    model = build_model(cfg, use_remat=True)
    params = model.init_params(jax.random.PRNGKey(seed))
    params = jax.device_put(params, tree_param_specs(params, mesh, cfg))

    opt_kw = {}
    if optimizer == "fednl":
        opt_kw = dict(k_per_block=curvature_k,
                      curvature="hutchinson" if hvp else "fisher")
    opt = make_optimizer(optimizer, lr, **opt_kw)
    # second-order curvature state (and first-order moments) carry the
    # params' own shardings — state scales with the shards, not one
    # chip's HBM.
    state_shape = jax.eval_shape(opt.init, params)
    opt_state = jax.jit(opt.init, out_shardings=opt_state_shardings(
        state_shape, params, mesh, cfg))(params)

    # every shard on the mesh data axis plays one FedNL silo for the
    # curvature observations (when the batch divides across them)
    n_silos = dict(zip(mesh.axis_names, mesh.devices.shape)).get("data", 1)
    if batch % max(n_silos, 1):
        n_silos = 1
    step_fn = jax.jit(make_train_step(
        model, opt, microbatches=microbatches, refresh_every=refresh_every,
        n_silos=n_silos, hvp=hvp, probe_seed=seed))

    # host-side wire accounting: what one curvature refresh ships
    # (per-silo Block-TopK diff payloads, every param tensor)
    curv_bits = (opt.uplink_bits(params, n_silos=n_silos)
                 if opt.uplink_bits is not None else 0)
    if curv_bits:
        print(f"curvature uplink: {curv_bits} bits/refresh "
              f"({n_silos} silo(s), refresh_every={refresh_every})",
              flush=True)

    t_text = seq - (cfg.vision_tokens if cfg.family == "vlm" else 0)
    pipe = TokenPipeline(vocab_size=cfg.vocab, seq_len=t_text,
                         global_batch=batch, seed=seed)
    history = []
    refreshes = 0
    t0 = time.time()
    for i in range(steps):
        b = add_modality_inputs(pipe.batch(i), cfg, i)
        params, opt_state, metrics = step_fn(params, opt_state, b)
        history.append(float(metrics["loss"]))
        refreshes += int(metrics.get("curv_refreshed", 0.0))
        if i % log_every == 0 or i == steps - 1:
            extra = (f" curv_bits {curv_bits * refreshes}"
                     if curv_bits else "")
            print(f"step {i:5d} loss {history[-1]:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}"
                  f"{extra} ({(time.time()-t0):.1f}s)", flush=True)
    if ckpt:
        save_ckpt(ckpt, {"params": params}, step=steps)
        print(f"checkpoint -> {ckpt}")
    return history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCHS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--optimizer", default="adamw",
                    choices=["adamw", "sgd", "fednl"])
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--refresh-every", type=int, default=4,
                    help="curvature refresh interval (fednl): observe + "
                         "learn H every N steps, precondition every step")
    ap.add_argument("--curvature-k", type=int, default=2048,
                    help="Block-TopK k per 128x128 block for the "
                         "curvature-diff uplink (fednl)")
    ap.add_argument("--hvp", action="store_true",
                    help="Hutchinson z*(Hz) curvature probes (one "
                         "jvp-of-grad per silo per refresh) instead of "
                         "the empirical-Fisher g^2 diagonal")
    ap.add_argument("--ckpt", default=None)
    args = ap.parse_args()
    train(args.arch, smoke=args.smoke, steps=args.steps, batch=args.batch,
          seq=args.seq, lr=args.lr, optimizer=args.optimizer,
          microbatches=args.microbatches, ckpt=args.ckpt,
          refresh_every=args.refresh_every, curvature_k=args.curvature_k,
          hvp=args.hvp)


if __name__ == "__main__":
    main()
