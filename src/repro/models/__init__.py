from .config import ModelConfig
from .transformer import Model, build_model
