"""Attention: GQA (+ RoPE, sliding window, QKV bias) and MLA (MiniCPM3-style
multi-head latent attention with decoupled RoPE), with train forward and
single-token decode against a KV cache.

Cache layouts:
  GQA: {"k": (B, S, KV, hd), "v": (B, S, KV, hd)}
  MLA: {"ckv": (B, S, kv_lora), "krope": (B, S, rope_dim)}  — the latent
       cache is what makes MLA's decode memory small.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import apply_rope, causal_mask, decode_mask, dense_init, shard_act
from .config import ModelConfig


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, h * hd, cfg.jdtype),
        "wk": dense_init(ks[1], d, kv * hd, cfg.jdtype),
        "wv": dense_init(ks[2], d, kv * hd, cfg.jdtype),
        "wo": dense_init(ks[3], h * hd, d, cfg.jdtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h * hd,), cfg.jdtype)
        p["bk"] = jnp.zeros((kv * hd,), cfg.jdtype)
        p["bv"] = jnp.zeros((kv * hd,), cfg.jdtype)
    return p


def _qkv(p, x, cfg: ModelConfig):
    b, t, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(b, t, h, hd), k.reshape(b, t, kv, hd), v.reshape(b, t, kv, hd))


def _sdpa(q, k, v, mask, n_rep: int):
    """q: (B,T,H,hd); k/v: (B,S,KV,hd); mask: (T,S) or (B,T,S) bool."""
    b, t, h, hd = q.shape
    s, kv = k.shape[1], k.shape[2]
    qg = q.reshape(b, t, kv, n_rep, hd)
    scores = jnp.einsum("btkrh,bskh->bkrts", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(hd))
    scores = jnp.where(mask[None, None, None] if mask.ndim == 2 else mask[:, None, None],
                       scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrts,bskh->btkrh", w, v)
    return out.reshape(b, t, h, hd)


# Query-chunk size for long sequences: bounds the live f32 score block to
# (B, H, CHUNK, S) instead of (B, H, T, S) — the XLA-path analogue of flash
# attention's tiling (the Pallas kernel does the full online-softmax version).
SDPA_CHUNK = 256


def _sdpa_chunked(q, k, v, n_rep: int, window, chunk: int = SDPA_CHUNK):
    """Causal attention, scanning over query chunks. q: (B,T,H,hd) with
    query i at absolute position i; k/v: (B,T,KV,hd)."""
    b, t, h, hd = q.shape
    pad = (-t) % chunk
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q.shape[1] // chunk
    qc = q.reshape(b, nc, chunk, h, hd).transpose(1, 0, 2, 3, 4)   # (nc,B,c,H,hd)

    @jax.checkpoint  # don't let autodiff stack per-chunk softmax weights
    def one_chunk(qi, ci):
        qpos = ci * chunk + jnp.arange(chunk)                      # (c,)
        j = jnp.arange(t)
        mask = j[None, :] <= qpos[:, None]
        if window is not None:
            mask = jnp.logical_and(mask, j[None, :] > qpos[:, None] - window)
        return _sdpa(qi, k, v, mask, n_rep)

    def body(_, inp):
        qi, ci = inp
        return (), one_chunk(qi, ci)

    _, out = jax.lax.scan(body, (), (qc, jnp.arange(nc)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nc * chunk, h, hd)
    return out[:, :t]


def gqa_forward(p, x, cfg: ModelConfig, positions=None):
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)[None, :]
    q, k, v = _qkv(p, x, cfg)
    if cfg.rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    q = shard_act(q, "bthd")
    k = shard_act(k, "bthd")
    v = shard_act(v, "bthd")
    if t > 2 * SDPA_CHUNK:
        out = _sdpa_chunked(q, k, v, cfg.n_heads // cfg.kv_heads,
                            cfg.sliding_window)
    else:
        mask = causal_mask(t, cfg.sliding_window)
        out = _sdpa(q, k, v, mask, cfg.n_heads // cfg.kv_heads)
    y = out.reshape(b, t, cfg.n_heads * cfg.hd) @ p["wo"]
    return shard_act(y, "btd"), {"k": k, "v": v}


def gqa_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    kv, hd = cfg.kv_heads, cfg.hd
    shape = (batch, max_len, kv, hd)
    return {"k": jnp.zeros(shape, cfg.jdtype), "v": jnp.zeros(shape, cfg.jdtype)}


def gqa_decode(p, x, cache, pos, cfg: ModelConfig):
    """x: (B, 1, d); pos: () int — absolute position of the new token."""
    b = x.shape[0]
    q, k, v = _qkv(p, x, cfg)
    posv = jnp.full((b, 1), pos, jnp.int32)
    if cfg.rope:
        q = apply_rope(q, posv, cfg.rope_theta)
        k = apply_rope(k, posv, cfg.rope_theta)
    cache = {
        "k": jax.lax.dynamic_update_slice_in_dim(cache["k"], k, pos, axis=1),
        "v": jax.lax.dynamic_update_slice_in_dim(cache["v"], v, pos, axis=1),
    }
    s = cache["k"].shape[1]
    mask = decode_mask(s, pos, cfg.sliding_window)[None, :]    # (1, S)
    out = _sdpa(q, cache["k"], cache["v"], mask, cfg.n_heads // cfg.kv_heads)
    y = out.reshape(b, 1, cfg.n_heads * cfg.hd) @ p["wo"]
    return y, cache


# ---------------------------------------------------------------------------
# Cross-attention (whisper decoder)
# ---------------------------------------------------------------------------


def cross_forward(p, x, memory, cfg: ModelConfig):
    """Full (non-causal) attention of x over encoder memory."""
    b, t, _ = x.shape
    s = memory.shape[1]
    h, kv, hd = cfg.n_heads, cfg.kv_heads, cfg.hd
    q = (x @ p["wq"]).reshape(b, t, h, hd)
    k = (memory @ p["wk"]).reshape(b, s, kv, hd)
    v = (memory @ p["wv"]).reshape(b, s, kv, hd)
    mask = jnp.ones((t, s), bool)
    out = _sdpa(q, k, v, mask, h // kv)
    return out.reshape(b, t, h * hd) @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (multi-head latent attention)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig):
    m = cfg.mla
    d, h = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_dim + m.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "wdq": dense_init(ks[0], d, m.q_lora_rank, cfg.jdtype),
        "wuq": dense_init(ks[1], m.q_lora_rank, h * qd, cfg.jdtype),
        "wdkv": dense_init(ks[2], d, m.kv_lora_rank, cfg.jdtype),
        "wkrope": dense_init(ks[3], d, m.qk_rope_dim, cfg.jdtype),
        "wuk": dense_init(ks[4], m.kv_lora_rank, h * m.qk_nope_dim, cfg.jdtype),
        "wuv": dense_init(ks[5], m.kv_lora_rank, h * m.v_head_dim, cfg.jdtype),
        "wo": dense_init(ks[6], h * m.v_head_dim, d, cfg.jdtype,
                         scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _mla_qk(p, x, positions, cfg: ModelConfig):
    m = cfg.mla
    b, t, _ = x.shape
    h = cfg.n_heads
    q = (x @ p["wdq"]) @ p["wuq"]
    q = q.reshape(b, t, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., : m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    ckv = x @ p["wdkv"]                                  # (b, t, kv_lora)
    krope = apply_rope((x @ p["wkrope"])[:, :, None, :], positions,
                       cfg.rope_theta)[:, :, 0, :]       # (b, t, rope_dim)
    return q_nope, q_rope, ckv, krope


def _mla_attend(p, q_nope, q_rope, ckv, krope, mask, cfg: ModelConfig):
    m = cfg.mla
    b, t, h, _ = q_nope.shape
    s = ckv.shape[1]
    k_nope = (ckv @ p["wuk"]).reshape(b, s, h, m.qk_nope_dim)
    v = (ckv @ p["wuv"]).reshape(b, s, h, m.v_head_dim)
    scores = (jnp.einsum("bthd,bshd->bhts", q_nope, k_nope)
              + jnp.einsum("bthd,bsd->bhts", q_rope,
                           jnp.broadcast_to(krope[:, :, :], (b, s, m.qk_rope_dim)))
              ).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(m.qk_nope_dim + m.qk_rope_dim))
    scores = jnp.where(mask[None, None] if mask.ndim == 2 else mask[:, None],
                       scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhts,bshd->bthd", w, v)
    return out.reshape(b, t, h * m.v_head_dim) @ p["wo"]


def mla_forward(p, x, cfg: ModelConfig, positions=None):
    b, t, _ = x.shape
    if positions is None:
        positions = jnp.arange(t)[None, :]
    q_nope, q_rope, ckv, krope = _mla_qk(p, x, positions, cfg)
    if t > 2 * SDPA_CHUNK:
        y = _mla_attend_chunked(p, q_nope, q_rope, ckv, krope, cfg)
    else:
        mask = causal_mask(t, cfg.sliding_window)
        y = _mla_attend(p, q_nope, q_rope, ckv, krope, mask, cfg)
    return shard_act(y, "btd"), {"ckv": ckv, "krope": krope}


def _mla_attend_chunked(p, q_nope, q_rope, ckv, krope, cfg: ModelConfig,
                        chunk: int = SDPA_CHUNK):
    b, t, h, _ = q_nope.shape
    pad = (-t) % chunk
    if pad:
        q_nope = jnp.pad(q_nope, ((0, 0), (0, pad), (0, 0), (0, 0)))
        q_rope = jnp.pad(q_rope, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = q_nope.shape[1] // chunk
    qn = q_nope.reshape(b, nc, chunk, h, -1).transpose(1, 0, 2, 3, 4)
    qr = q_rope.reshape(b, nc, chunk, h, -1).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint  # see _sdpa_chunked
    def one_chunk(qni, qri, ci):
        qpos = ci * chunk + jnp.arange(chunk)
        mask = jnp.arange(t)[None, :] <= qpos[:, None]
        return _mla_attend(p, qni, qri, ckv, krope, mask, cfg)

    def body(_, inp):
        qni, qri, ci = inp
        return (), one_chunk(qni, qri, ci)

    _, out = jax.lax.scan(body, (), (qn, qr, jnp.arange(nc)))
    out = out.transpose(1, 0, 2, 3).reshape(b, nc * chunk, -1)
    return out[:, :t]


def mla_init_cache(cfg: ModelConfig, batch: int, max_len: int):
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), cfg.jdtype),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_dim), cfg.jdtype),
    }


def mla_decode(p, x, cache, pos, cfg: ModelConfig):
    b = x.shape[0]
    posv = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, ckv, krope = _mla_qk(p, x, posv, cfg)
    cache = {
        "ckv": jax.lax.dynamic_update_slice_in_dim(cache["ckv"], ckv, pos, axis=1),
        "krope": jax.lax.dynamic_update_slice_in_dim(cache["krope"], krope, pos, axis=1),
    }
    s = cache["ckv"].shape[1]
    mask = decode_mask(s, pos)[None, :]
    y = _mla_attend(p, q_nope, q_rope, cache["ckv"], cache["krope"], mask, cfg)
    return y, cache
