"""Shared building blocks: initializers, norms, RoPE, masking, sharding hooks."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Activation-sharding hook. The launcher installs a mesh-aware constraint
# function; models call shard_act(x, kind) at a few strategic points. In
# unit tests (no mesh) this is the identity.
# ---------------------------------------------------------------------------

_ACT_CONSTRAINT = None  # Callable[(Array, str) -> Array] | None
_LAYER_PARAM_CONSTRAINT = None  # Callable[(pytree) -> pytree] | None


def set_activation_sharder(fn, layer_param_fn=None) -> None:
    global _ACT_CONSTRAINT, _LAYER_PARAM_CONSTRAINT
    _ACT_CONSTRAINT = fn
    _LAYER_PARAM_CONSTRAINT = layer_param_fn


def shard_act(x: jax.Array, kind: str) -> jax.Array:
    """kind in {'btd', 'btf', 'bthd', 'logits'} — see launch/sharding.py."""
    if _ACT_CONSTRAINT is None:
        return x
    return _ACT_CONSTRAINT(x, kind)


def shard_layer_params(tree):
    """Pin the per-layer param slice (inside the scan body) to its natural
    sharding. Without this XLA hoists the FSDP all-gather of the *stacked*
    scan parameters out of the loop — peak memory then holds every layer's
    weights unsharded at once."""
    if _LAYER_PARAM_CONSTRAINT is None:
        return tree
    return _LAYER_PARAM_CONSTRAINT(tree)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, dtype, scale: float = 1.0) -> jax.Array:
    std = scale / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * std).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dtype) * w


def layernorm(x: jax.Array, w: jax.Array, b: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    mean = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mean) * jax.lax.rsqrt(var + eps)).astype(dtype) * w + b


def norm_params(key, d: int, kind: str, dtype):
    if kind == "rmsnorm":
        return {"w": jnp.ones((d,), dtype)}
    return {"w": jnp.ones((d,), dtype), "b": jnp.zeros((d,), dtype)}


def apply_norm(x, p, kind: str):
    if kind == "rmsnorm":
        return rmsnorm(x, p["w"])
    return layernorm(x, p["w"], p["b"])


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., T, H, hd); positions: (..., T) int32. Rotates pairs."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                       # (hd/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, hd/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., T, 1, hd/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------


def causal_mask(t: int, window: Optional[int] = None) -> jax.Array:
    """(t, t) bool, True = attendable. Optional sliding window."""
    i = jnp.arange(t)[:, None]
    j = jnp.arange(t)[None, :]
    mask = j <= i
    if window is not None:
        mask = jnp.logical_and(mask, j > i - window)
    return mask


def decode_mask(cache_len: int, pos: jax.Array, window: Optional[int] = None) -> jax.Array:
    """(cache_len,) bool for one query at absolute position ``pos``."""
    j = jnp.arange(cache_len)
    mask = j <= pos
    if window is not None:
        mask = jnp.logical_and(mask, j > pos - window)
    return mask


def cross_entropy(logits: jax.Array, targets: jax.Array) -> jax.Array:
    """Mean token cross-entropy; logits (..., V), targets (...) int.

    The gold logit is picked with an iota-mask reduce rather than
    take_along_axis: a gather across the vocab dimension would force
    GSPMD to all-gather the (B, T, V) logits when V is sharded over the
    'model' axis, while iota+select+reduce partitions cleanly (the mask
    fuses into the reduction, nothing is materialized)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    idx = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    gold = jnp.sum(jnp.where(idx == targets[..., None], logits, 0.0), axis=-1)
    return jnp.mean(logz - gold)
