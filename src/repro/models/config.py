"""Architecture configuration for the model zoo.

One dataclass covers all ten assigned architectures; the ``family`` field
selects the block program:

  dense    — uniform decoder blocks (GQA or MLA attention + MLP/MoE)
  moe      — dense with MoE feed-forward every layer
  hybrid   — Jamba-style period: Mamba x7 + attention x1, MoE every other
  ssm      — xLSTM: mLSTM blocks with one sLSTM per period
  encdec   — Whisper: encoder (stubbed audio frontend) + causal decoder
  vlm      — LLaVA: decoder LM consuming [vision patches ; tokens]

The modality frontends (mel+conv for audio, ViT for vision) are stubs by
explicit carve-out: ``input_specs`` supplies precomputed embeddings.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 8
    top_k: int = 2
    capacity_factor: float = 1.25
    group_size: int = 512          # routing group for one-hot dispatch
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    slstm_every: int = 8           # one sLSTM per this many blocks (7:1)
    chunk: int = 256               # chunkwise-parallel mLSTM chunk length
    proj_factor: float = 2.0       # ffn expansion inside blocks


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    kv_heads: int
    d_ff: int
    vocab: int
    head_dim: Optional[int] = None  # default d_model // n_heads
    # attention
    attn_type: str = "gqa"         # gqa | mla
    rope: bool = True
    rope_theta: float = 10000.0
    qkv_bias: bool = False         # qwen2
    sliding_window: Optional[int] = None  # starcoder2: 4096
    mla: Optional[MLAConfig] = None
    # mlp
    mlp_type: str = "swiglu"       # swiglu | gelu
    # moe
    moe: Optional[MoEConfig] = None
    moe_every: int = 1             # MoE layer period (jamba: 2)
    # hybrid / ssm
    mamba: Optional[MambaConfig] = None
    attn_every: int = 8            # jamba: 1 attention per 8 layers
    xlstm: Optional[XLSTMConfig] = None
    # enc-dec (whisper)
    enc_layers: int = 0
    enc_seq: int = 1500            # audio frames after conv stub
    # vlm (llava)
    vision_tokens: int = 0         # prepended patch embeddings (anyres stub)
    # norm & misc
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # citation (source of the numbers)
    source: str = ""

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def jdtype(self):
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    @property
    def supports_long_decode(self) -> bool:
        """True if a 524k-token decode state is sub-quadratic/windowed."""
        if self.family in ("hybrid", "ssm"):
            return True
        return self.sliding_window is not None

    def reduced(self, n_layers: int = 2, d_model: int = 256, d_ff: int = 512,
                vocab: int = 512, experts: int = 4) -> "ModelConfig":
        """Smoke-test variant of the same family (<=512 wide, <=4 experts)."""
        heads = max(2, min(4, self.n_heads))
        kv = max(1, min(heads, self.kv_heads if self.kv_heads < self.n_heads else heads))
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(
                self.moe, num_experts=min(experts, self.moe.num_experts),
                top_k=min(2, self.moe.top_k), group_size=64)
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=64, kv_lora_rank=32, qk_nope_dim=32,
                            qk_rope_dim=16, v_head_dim=32)
        xl = None
        if self.xlstm is not None:
            xl = dataclasses.replace(self.xlstm, slstm_every=2, chunk=32)
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=d_model,
            n_heads=heads,
            kv_heads=kv,
            d_ff=d_ff,
            vocab=vocab,
            head_dim=d_model // heads,
            moe=moe,
            mla=mla,
            xlstm=xl,
            attn_every=2 if self.family == "hybrid" else self.attn_every,
            moe_every=self.moe_every,
            enc_layers=min(2, self.enc_layers) if self.enc_layers else 0,
            enc_seq=32 if self.enc_layers else self.enc_seq,
            vision_tokens=16 if self.vision_tokens else 0,
            sliding_window=16 if self.sliding_window else None,
            dtype="float32",
        )
