"""Mamba (selective SSM) block for the Jamba hybrid stack.

Standard Mamba-1 structure: in_proj -> (u, z); short causal depthwise
conv; data-dependent (Delta, B, C) projections; diagonal selective SSM

    h_t = exp(Delta_t A) h_{t-1} + Delta_t B_t u_t
    y_t = C_t . h_t + D u_t

Training uses ``jax.lax.associative_scan`` over time (log-depth on TPU —
this is the TPU-native adaptation of the CUDA selective-scan kernel).
Decode keeps (conv window, ssm state) as the per-layer cache — O(1) per
token, which is why Jamba runs the 524k-token shape.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, shard_act
from .config import ModelConfig


def mamba_init(key, cfg: ModelConfig):
    m = cfg.mamba
    d = cfg.d_model
    di = m.expand * d
    ks = jax.random.split(key, 7)
    # S4D-real initialization for A
    a = -jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32)[None], (di, 1))
    return {
        "win": dense_init(ks[0], d, 2 * di, cfg.jdtype),
        "conv": (jax.random.normal(ks[1], (m.d_conv, di)) / m.d_conv).astype(cfg.jdtype),
        "conv_b": jnp.zeros((di,), cfg.jdtype),
        "wbc": dense_init(ks[2], di, 2 * m.d_state, cfg.jdtype),
        "wdt": dense_init(ks[3], di, 1, cfg.jdtype),       # rank-1 Delta proj
        "dt_bias": (jnp.log(jnp.expm1(jnp.exp(
            jax.random.uniform(ks[4], (di,), minval=jnp.log(1e-3), maxval=jnp.log(1e-1))
        )))).astype(jnp.float32),
        "a_log": jnp.log(-a),                               # (di, S) fp32
        "d_skip": jnp.ones((di,), jnp.float32),
        "wout": dense_init(ks[5], di, d, cfg.jdtype,
                           scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


SSM_CHUNK = 256


def _ssm_scan(u, dt, b, c, a, chunk: int = SSM_CHUNK):
    """u: (B,T,Di); dt: (B,T,Di); b,c: (B,T,S); a: (Di,S). Returns (B,T,Di).

    Recurrence h_t = decay_t h_{t-1} + inc_t with decay_t = exp(dt_t a),
    inc_t = dt_t b_t u_t (outer over the state dim).

    Memory note: a flat associative_scan over T materializes the
    (B, T, Di, S) decay/increment tensors — for Jamba's Di = 16384 at
    T = 4096 that is ~17 GB fp32 *per tensor per device*. We therefore
    run a sequential lax.scan over chunks of ``chunk`` steps carrying the
    (B, Di, S) state, with the log-depth associative scan only *inside*
    a chunk (still parallel on the VPU) and remat around each chunk so
    autodiff stores one chunk's tensors at a time.
    """

    def combine(x, y):
        d1, i1 = x
        d2, i2 = y
        return d1 * d2, i1 * d2 + i2

    b_, t, di = u.shape
    s = b.shape[-1]
    if t <= chunk:
        decay = jnp.exp(dt[..., None] * a[None, None])
        inc = (dt * u)[..., None] * b[:, :, None, :]
        _, h = jax.lax.associative_scan(combine, (decay, inc), axis=1)
        return jnp.einsum("btds,bts->btd", h, c)

    pad = (-t) % chunk
    if pad:
        zp = lambda x: jnp.pad(x, ((0, 0), (0, pad)) + ((0, 0),) * (x.ndim - 2))
        u, dt, b, c = zp(u), zp(dt), zp(b), zp(c)
    nc = (t + pad) // chunk
    split = lambda x: x.reshape(b_, nc, chunk, *x.shape[2:]).transpose(
        1, 0, 2, *range(3, x.ndim + 1))
    uc, dtc, bc, cc = split(u), split(dt), split(b), split(c)

    @jax.checkpoint
    def one_chunk(h0, ui, dti, bi, ci):
        decay = jnp.exp(dti[..., None] * a[None, None])      # (B,chunk,Di,S)
        inc = (dti * ui)[..., None] * bi[:, :, None, :]
        # fold the carried state into the first increment
        inc = inc.at[:, 0].add(decay[:, 0] * h0)
        _, h = jax.lax.associative_scan(combine, (decay, inc), axis=1)
        y = jnp.einsum("btds,bts->btd", h, ci)
        return h[:, -1], y

    def body(h0, xs):
        ui, dti, bi, ci = xs
        return one_chunk(h0, ui, dti, bi, ci)

    h_init = jnp.zeros((b_, di, s), u.dtype)
    _, ys = jax.lax.scan(body, h_init, (uc, dtc, bc, cc))
    y = ys.transpose(1, 0, 2, 3).reshape(b_, t + pad, di)
    return y[:, :t]


def mamba_forward(p, x, cfg: ModelConfig):
    m = cfg.mamba
    b_, t, d = x.shape
    uz = x @ p["win"]
    u, z = jnp.split(uz, 2, axis=-1)                        # (B,T,Di) each

    # causal depthwise conv over the last d_conv steps
    u_pad = jnp.pad(u, ((0, 0), (m.d_conv - 1, 0), (0, 0)))
    conv = sum(u_pad[:, i : i + t] * p["conv"][i] for i in range(m.d_conv))
    u = jax.nn.silu(conv + p["conv_b"])
    u = shard_act(u, "btf")

    bc = u @ p["wbc"]
    b_in, c_in = jnp.split(bc.astype(jnp.float32), 2, axis=-1)   # (B,T,S)
    dt = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    y = _ssm_scan(u.astype(jnp.float32), dt, b_in, c_in, a)
    y = y + p["d_skip"] * u.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["wout"]
    return shard_act(y, "btd")


def mamba_init_cache(cfg: ModelConfig, batch: int):
    m = cfg.mamba
    di = m.expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), cfg.jdtype),
        "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32),
    }


def mamba_decode(p, x, cache, cfg: ModelConfig):
    """x: (B, 1, d); O(1) state update."""
    b_, _, d = x.shape
    uz = x @ p["win"]
    u, z = jnp.split(uz, 2, axis=-1)                        # (B,1,Di)

    window = jnp.concatenate([cache["conv"], u], axis=1)    # (B, d_conv, Di)
    conv = jnp.einsum("bkd,kd->bd", window, p["conv"])[:, None]
    u_act = jax.nn.silu(conv + p["conv_b"])

    bc = u_act @ p["wbc"]
    b_in, c_in = jnp.split(bc.astype(jnp.float32), 2, axis=-1)
    dt = jax.nn.softplus((u_act @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["a_log"])

    decay = jnp.exp(dt[:, 0, :, None] * a[None])            # (B,Di,S)
    inc = (dt[:, 0] * u_act[:, 0].astype(jnp.float32))[..., None] * b_in[:, 0, None, :]
    ssm = cache["ssm"] * decay + inc
    y = jnp.einsum("bds,bs->bd", ssm, c_in[:, 0])[:, None]
    y = y + p["d_skip"] * u_act.astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z)) @ p["wout"]

    cache = {"conv": window[:, 1:], "ssm": ssm}
    return y, cache
