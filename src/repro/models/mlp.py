"""Feed-forward blocks: dense MLP (swiglu / gelu) and capacity-based MoE.

The MoE dispatch is the GSPMD-friendly one-hot/capacity formulation
(Switch-Transformer style): tokens are routed in fixed-size groups, each
expert takes at most C = ceil(top_k * group * cf / E) tokens per group,
and dispatch/combine are einsums — all static shapes, MXU-friendly, and
shardable with experts over the "model" mesh axis (all-to-all inserted by
GSPMD at the (group, expert) boundary). Overflow tokens are dropped
(standard capacity semantics); an auxiliary load-balance loss keeps the
router near-uniform.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .common import dense_init, shard_act
from .config import ModelConfig


# ---------------------------------------------------------------------------
# Dense MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: int | None = None):
    d, ff = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.mlp_type == "swiglu":
        return {
            "wi": dense_init(ks[0], d, ff, cfg.jdtype),
            "wg": dense_init(ks[1], d, ff, cfg.jdtype),
            "wo": dense_init(ks[2], ff, d, cfg.jdtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
        }
    return {
        "wi": dense_init(ks[0], d, ff, cfg.jdtype),
        "wo": dense_init(ks[2], ff, d, cfg.jdtype, scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def mlp_forward(p, x, cfg: ModelConfig):
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    else:
        h = jax.nn.gelu(x @ p["wi"])
    h = shard_act(h, "btf")
    return x_out_cast(h @ p["wo"], x)


def x_out_cast(y, x):
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def moe_init(key, cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    ks = jax.random.split(key, 4)
    scale_o = 1.0 / (2 * cfg.n_layers) ** 0.5

    def stack(k, din, dout, scale=1.0):
        keys = jax.random.split(k, e)
        return jnp.stack([dense_init(kk, din, dout, cfg.jdtype, scale) for kk in keys])

    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "wi": stack(ks[1], d, ff),
        "wo": stack(ks[2], ff, d, scale_o),
    }
    if cfg.mlp_type == "swiglu":
        p["wg"] = stack(ks[3], d, ff)
    return p


def _route(router_logits: jax.Array, cfg: ModelConfig):
    """router_logits: (G, E). Returns dispatch (G, E, C) bool-ish,
    combine (G, E, C) float, aux loss scalar."""
    moe = cfg.moe
    g, e = router_logits.shape
    k = moe.top_k
    c = max(1, math.ceil(k * g * moe.capacity_factor / e))

    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gate_vals, gate_ids = jax.lax.top_k(probs, k)                  # (G, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # slot-major priority: slot 0 of every token first
    masks = jax.nn.one_hot(gate_ids, e, dtype=jnp.float32)         # (G, k, E)
    flat = masks.transpose(1, 0, 2).reshape(k * g, e)              # (k*G, E)
    pos = jnp.cumsum(flat, axis=0) - flat                          # position in expert
    keep = (pos < c) * flat                                        # drop overflow
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), c, dtype=jnp.float32)
    disp_flat = keep[..., None] * pos_oh                           # (k*G, E, C)
    disp = disp_flat.reshape(k, g, e, c).transpose(1, 0, 2, 3)     # (G, k, E, C)

    combine = jnp.einsum("gk,gkec->gec", gate_vals, disp)
    dispatch = jnp.sum(disp, axis=1)                               # (G, E, C)

    # load-balance auxiliary loss (Switch eq. 4)
    frac_tokens = jnp.mean(jnp.sum(masks, axis=1), axis=0)         # (E,)
    frac_probs = jnp.mean(probs, axis=0)
    aux = e * jnp.sum(frac_tokens * frac_probs)
    return dispatch, combine, aux


def moe_forward(p, x, cfg: ModelConfig):
    """x: (B, T, d) -> (y, aux_loss)."""
    b, t, d = x.shape
    moe = cfg.moe
    gsz = min(moe.group_size, b * t)
    tokens = x.reshape(-1, d)
    n_tok = tokens.shape[0]
    pad = (-n_tok) % gsz
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    groups = shard_act(tokens.reshape(-1, gsz, d), "moe_route")    # (NG, G, d)

    logits = jnp.einsum("ngd,de->nge", groups.astype(jnp.float32), p["router"])
    dispatch, combine, aux = jax.vmap(lambda l: _route(l, cfg))(logits)
    dispatch = shard_act(dispatch, "moe_route")
    combine = shard_act(combine, "moe_route")
    aux = jnp.mean(aux)

    xin = jnp.einsum("ngec,ngd->necd", dispatch.astype(groups.dtype), groups)
    xin = shard_act(xin, "ecf")
    # expert MLP, batched over (NG, E)
    if cfg.mlp_type == "swiglu":
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", xin, p["wg"])) \
            * jnp.einsum("necd,edf->necf", xin, p["wi"])
    else:
        h = jax.nn.gelu(jnp.einsum("necd,edf->necf", xin, p["wi"]))
    h = shard_act(h, "ecf")
    xout = jnp.einsum("necf,efd->necd", h, p["wo"])
    y = jnp.einsum("ngec,necd->ngd", combine.astype(groups.dtype), xout)

    y = y.reshape(-1, d)
    if pad:
        y = y[:n_tok]
    return y.reshape(b, t, d), aux
