"""Model assembly: block programs per family, scan-over-layers with remat,
train loss / prefill / single-token decode.

Families
  dense | moe : uniform decoder blocks, one lax.scan over all layers
  hybrid      : Jamba periods (attn_every-1 Mamba + 1 attention; MoE on
                odd layers) — scan over periods
  ssm         : xLSTM periods (slstm_every-1 mLSTM + 1 sLSTM)
  encdec      : Whisper — encoder scan + decoder scan with cross-attention
  vlm         : LLaVA — dense LM consuming [patch embeddings ; tokens]

All parameters are plain nested dicts of jnp arrays (stacked on a leading
layer/period axis for scanned segments); sharding is attached by path
rules in launch/sharding.py so model code stays mesh-free apart from
``shard_act`` hints.
"""

from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from . import attention as attn, mamba as mam, mlp as ff, xlstm as xl
from .common import (
    apply_norm,
    cross_entropy,
    embed_init,
    norm_params,
    shard_act,
    shard_layer_params,
)
from .config import ModelConfig

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Block program
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ModelConfig) -> list[tuple[str, str]]:
    """(mixer, ffn) kind per decoder layer."""
    kinds = []
    for i in range(cfg.n_layers):
        if cfg.family == "hybrid":
            mixer = "attn" if (i % cfg.attn_every == cfg.attn_every - 1) else "mamba"
            ffn = "moe" if (cfg.moe is not None and i % cfg.moe_every == 1) else "mlp"
        elif cfg.family == "ssm":
            mixer = "slstm" if (i % cfg.xlstm.slstm_every == cfg.xlstm.slstm_every - 1) \
                else "mlstm"
            ffn = "none" if cfg.d_ff == 0 else "mlp"
        else:
            mixer = "mla" if cfg.attn_type == "mla" else "attn"
            ffn = "moe" if cfg.moe is not None else "mlp"
        kinds.append((mixer, ffn))
    return kinds


def period_len(cfg: ModelConfig) -> int:
    """Layers per scanned segment (1 for uniform stacks)."""
    if cfg.family == "hybrid":
        p = cfg.attn_every
        if cfg.moe is not None:
            p = max(p, 2) if p % 2 == 0 else p * 2
        return p
    if cfg.family == "ssm":
        return cfg.xlstm.slstm_every
    return 1


# ---------------------------------------------------------------------------
# Single-layer init / forward / decode
# ---------------------------------------------------------------------------

_MIXER_INIT = {"attn": attn.gqa_init, "mla": attn.mla_init,
               "mamba": mam.mamba_init, "mlstm": xl.mlstm_init,
               "slstm": xl.slstm_init}


def _layer_init(key, cfg: ModelConfig, mixer: str, ffn: str, cross: bool):
    ks = jax.random.split(key, 6)
    p = {
        "norm1": norm_params(ks[0], cfg.d_model, cfg.norm, cfg.jdtype),
        "mixer": _MIXER_INIT[mixer](ks[1], cfg),
    }
    if ffn != "none":
        p["norm2"] = norm_params(ks[2], cfg.d_model, cfg.norm, cfg.jdtype)
        p["ffn"] = ff.moe_init(ks[3], cfg) if ffn == "moe" else ff.mlp_init(ks[3], cfg)
    if cross:
        p["norm_x"] = norm_params(ks[4], cfg.d_model, cfg.norm, cfg.jdtype)
        p["cross"] = attn.gqa_init(ks[5], cfg)
    return p


def _layer_forward(p, x, cfg: ModelConfig, mixer: str, ffn: str,
                   memory: Optional[jax.Array] = None, causal: bool = True):
    h = apply_norm(x, p["norm1"], cfg.norm)
    if mixer == "attn":
        if causal:
            y, _ = attn.gqa_forward(p["mixer"], h, cfg)
        else:  # encoder self-attention
            b, t, _ = h.shape
            q, k, v = attn._qkv(p["mixer"], h, cfg)
            mask = jnp.ones((t, t), bool)
            out = attn._sdpa(q, k, v, mask, cfg.n_heads // cfg.kv_heads)
            y = out.reshape(b, t, -1) @ p["mixer"]["wo"]
    elif mixer == "mla":
        y, _ = attn.mla_forward(p["mixer"], h, cfg)
    elif mixer == "mamba":
        y = mam.mamba_forward(p["mixer"], h, cfg)
    elif mixer == "mlstm":
        y = xl.mlstm_forward(p["mixer"], h, cfg)
    else:  # slstm
        y = xl.slstm_forward(p["mixer"], h, cfg)
    x = x + y

    if memory is not None:
        hx = apply_norm(x, p["norm_x"], cfg.norm)
        x = x + attn.cross_forward(p["cross"], hx, memory, cfg)

    aux = jnp.zeros((), jnp.float32)
    if ffn == "moe":
        h2 = apply_norm(x, p["norm2"], cfg.norm)
        y2, aux = ff.moe_forward(p["ffn"], h2, cfg)
        x = x + y2
    elif ffn == "mlp":
        h2 = apply_norm(x, p["norm2"], cfg.norm)
        x = x + ff.mlp_forward(p["ffn"], h2, cfg)
    return x, aux


def _layer_init_cache(cfg: ModelConfig, mixer: str, batch: int, max_len: int):
    if mixer == "attn":
        return attn.gqa_init_cache(cfg, batch, max_len)
    if mixer == "mla":
        return attn.mla_init_cache(cfg, batch, max_len)
    if mixer == "mamba":
        return mam.mamba_init_cache(cfg, batch)
    if mixer == "mlstm":
        return xl.mlstm_init_cache(cfg, batch)
    return xl.slstm_init_cache(cfg, batch)


def _layer_decode(p, x, cache, pos, cfg: ModelConfig, mixer: str, ffn: str,
                  memory: Optional[jax.Array] = None):
    h = apply_norm(x, p["norm1"], cfg.norm)
    if mixer == "attn":
        y, cache = attn.gqa_decode(p["mixer"], h, cache, pos, cfg)
    elif mixer == "mla":
        y, cache = attn.mla_decode(p["mixer"], h, cache, pos, cfg)
    elif mixer == "mamba":
        y, cache = mam.mamba_decode(p["mixer"], h, cache, cfg)
    elif mixer == "mlstm":
        y, cache = xl.mlstm_decode(p["mixer"], h, cache, cfg)
    else:
        y, cache = xl.slstm_decode(p["mixer"], h, cache, cfg)
    x = x + y

    if memory is not None:
        hx = apply_norm(x, p["norm_x"], cfg.norm)
        x = x + attn.cross_forward(p["cross"], hx, memory, cfg)

    if ffn != "none":
        h2 = apply_norm(x, p["norm2"], cfg.norm)
        if ffn == "moe":
            y2, _ = ff.moe_forward(p["ffn"], h2, cfg)
            x = x + y2
        else:
            x = x + ff.mlp_forward(p["ffn"], h2, cfg)
    return x, cache


# ---------------------------------------------------------------------------
# Sinusoidal positions (whisper — works at any length, no table)
# ---------------------------------------------------------------------------


def sinusoid(t: int, d: int, dtype) -> jax.Array:
    pos = jnp.arange(t, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1).astype(dtype)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------


class Model:
    """Functional model wrapper: params are explicit pytrees."""

    def __init__(self, cfg: ModelConfig, use_remat: bool = True,
                 unroll: bool = False):
        """``unroll=True`` replaces the layer scans with Python loops so
        XLA cost analysis counts every layer (used by the dry-run's cost
        probes — scan bodies are otherwise counted once)."""
        self.cfg = cfg
        self.use_remat = use_remat
        self.unroll = unroll
        self.kinds = layer_kinds(cfg)
        self.period = period_len(cfg)
        assert cfg.n_layers % self.period == 0, (cfg.n_layers, self.period)
        self.n_segments = cfg.n_layers // self.period

    # -- init ----------------------------------------------------------------

    def init_params(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 8)
        params: Params = {
            "embed": embed_init(ks[0], cfg.vocab, cfg.d_model, cfg.jdtype),
            "norm_f": norm_params(ks[1], cfg.d_model, cfg.norm, cfg.jdtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(ks[2], cfg.vocab, cfg.d_model, cfg.jdtype)

        # decoder stack: stack per-period params along axis 0
        def init_segment(seg_key):
            kk = jax.random.split(seg_key, self.period)
            seg = []
            for j in range(self.period):
                mixer, ffn = self.kinds[j]          # same pattern in every period
                seg.append(_layer_init(kk[j], cfg, mixer, ffn,
                                       cross=cfg.family == "encdec"))
            return seg

        seg_keys = jax.random.split(ks[3], self.n_segments)
        segments = [init_segment(k) for k in seg_keys]
        # stack: layers[j] is the j-th block within a period, stacked over periods
        params["layers"] = [
            jax.tree.map(lambda *xs: jnp.stack(xs), *[s[j] for s in segments])
            for j in range(self.period)
        ]

        if cfg.family == "encdec":
            enc_keys = jax.random.split(ks[4], cfg.enc_layers)
            enc = [_layer_init(k, cfg, "attn", "mlp", cross=False) for k in enc_keys]
            params["enc_layers"] = jax.tree.map(lambda *xs: jnp.stack(xs), *enc)
            params["enc_norm_f"] = norm_params(ks[5], cfg.d_model, cfg.norm, cfg.jdtype)
        return params

    # -- embedding frontends ----------------------------------------------------

    def _embed_inputs(self, params, batch):
        cfg = self.cfg
        x = params["embed"][batch["tokens"]]
        if cfg.family == "vlm":
            x = jnp.concatenate([batch["patches"].astype(x.dtype), x], axis=1)
        if cfg.family == "encdec":
            t = x.shape[1]
            x = x + sinusoid(t, cfg.d_model, x.dtype)
        return shard_act(x, "btd")

    def _encode(self, params, frames):
        cfg = self.cfg
        x = frames.astype(cfg.jdtype) + sinusoid(frames.shape[1], cfg.d_model,
                                                 cfg.jdtype)

        def body(x, p):
            p = shard_layer_params(p)
            y, _ = _layer_forward(p, x, cfg, "attn", "mlp", causal=False)
            return y, None

        if self.use_remat:
            body = jax.checkpoint(body)
        if self.unroll:
            for i in range(cfg.enc_layers):
                x, _ = body(x, jax.tree.map(lambda a: a[i], params["enc_layers"]))
        else:
            x, _ = jax.lax.scan(body, x, params["enc_layers"])
        return apply_norm(x, params["enc_norm_f"], cfg.norm)

    # -- decoder stack ------------------------------------------------------------

    def _stack_forward(self, params, x, memory=None):
        cfg = self.cfg

        def body(carry, seg_params):
            x, aux = carry
            seg_params = shard_layer_params(seg_params)
            for j in range(self.period):
                mixer, ffn = self.kinds[j]
                x, a = _layer_forward(seg_params[j], x, cfg, mixer, ffn,
                                      memory=memory)
                aux = aux + a
            x = shard_act(x, "carry")   # seq-parallel remat stash
            return (x, aux), None

        if self.use_remat:
            body = jax.checkpoint(body)

        # zip the per-period param list into a single scanned pytree (tuple)
        stacked = tuple(params["layers"])
        carry = (x, jnp.zeros((), jnp.float32))
        if self.unroll:
            for i in range(self.n_segments):
                seg = jax.tree.map(lambda a: a[i], stacked)
                carry, _ = body(carry, seg)
            x, aux = carry
        else:
            (x, aux), _ = jax.lax.scan(lambda c, p: body(c, p), carry, stacked)
        return x, aux

    def _logits(self, params, x):
        cfg = self.cfg
        x = apply_norm(x, params["norm_f"], cfg.norm)
        head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
        logits = jnp.einsum("btd,vd->btv", x, head)
        return shard_act(logits, "logits")

    # -- public API -----------------------------------------------------------

    def forward(self, params, batch):
        memory = None
        if self.cfg.family == "encdec":
            memory = self._encode(params, batch["frames"])
        x = self._embed_inputs(params, batch)
        x, aux = self._stack_forward(params, x, memory=memory)
        return self._logits(params, x), aux

    def loss_fn(self, params, batch):
        cfg = self.cfg
        logits, aux = self.forward(params, batch)
        if cfg.family == "vlm":          # loss on text positions only
            logits = logits[:, self.cfg.vision_tokens:]
        loss = cross_entropy(logits, batch["targets"])
        if cfg.moe is not None:
            loss = loss + cfg.moe.router_aux_weight * aux
        return loss

    # -- serving ----------------------------------------------------------------

    def init_cache(self, batch: int, max_len: int):
        """Stacked (over periods) per-block caches + optional encoder memory."""
        cfg = self.cfg

        def one(j):
            mixer, _ = self.kinds[j]
            c = _layer_init_cache(cfg, mixer, batch, max_len)
            return jax.tree.map(
                lambda a: jnp.broadcast_to(a, (self.n_segments,) + a.shape), c)

        cache = {"blocks": [one(j) for j in range(self.period)]}
        if cfg.family == "encdec":
            cache["enc"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), cfg.jdtype)
        return cache

    def decode_step(self, params, cache, token, pos):
        """token: (B, 1) int32; pos: () int32 absolute position.
        Returns (logits (B, 1, V), new cache)."""
        cfg = self.cfg
        x = params["embed"][token]
        memory = cache.get("enc") if cfg.family == "encdec" else None
        if cfg.family == "encdec":
            # sinusoidal position for the new token
            x = x + sinusoid_at(pos, cfg.d_model, x.dtype)

        kinds = self.kinds[: self.period]

        def body(x, pcs):
            seg_params, seg_caches = pcs
            seg_params = shard_layer_params(seg_params)
            new_caches = []
            for j, (mixer, ffn) in enumerate(kinds):
                x, c2 = _layer_decode(seg_params[j], x, seg_caches[j], pos,
                                      cfg, mixer, ffn, memory=memory)
                new_caches.append(c2)
            return x, tuple(new_caches)

        xs = (tuple(params["layers"]), tuple(cache["blocks"]))
        if self.unroll:
            outs = []
            for i in range(self.n_segments):
                x, c2 = body(x, jax.tree.map(lambda a: a[i], xs))
                outs.append(c2)
            new_block_caches = jax.tree.map(lambda *a: jnp.stack(a), *outs)
        else:
            x, new_block_caches = jax.lax.scan(body, x, xs)

        logits = self._logits(params, x)
        out_cache = dict(cache)
        out_cache["blocks"] = list(new_block_caches)
        return logits, out_cache


def sinusoid_at(pos, d: int, dtype) -> jax.Array:
    dim = jnp.arange(d // 2, dtype=jnp.float32)
    ang = pos.astype(jnp.float32) / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)]).astype(dtype)


def build_model(cfg: ModelConfig, use_remat: bool = True) -> Model:
    return Model(cfg, use_remat=use_remat)
