"""xLSTM blocks (Beck et al., arXiv:2405.04517): mLSTM and sLSTM.

mLSTM — matrix-memory LSTM with exponential gating; per head h:
    C_t = f_t C_{t-1} + i_t v_t k_t^T        (hd x hd matrix memory)
    n_t = f_t n_{t-1} + i_t k_t
    y_t = C_t q_t / max(|n_t^T q_t|, exp(-m_t))   (stabilized)
Training uses the *chunkwise-parallel* form (the TPU adaptation of the
FlashLinearAttention-style recurrence): quadratic attention within chunks
of length ``chunk`` + a carried inter-chunk state — sub-quadratic overall,
O(1)-state decode.

sLSTM — scalar-memory LSTM with hidden-to-gate recurrence (inherently
sequential; lax.scan over time), one per ``slstm_every`` blocks (7:1).

This is a faithful-structure implementation with the stabilizer m_t
tracked in log space as in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import dense_init, shard_act
from .config import ModelConfig


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def mlstm_init(key, cfg: ModelConfig):
    d, h = cfg.d_model, cfg.n_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, d, cfg.jdtype),
        "wk": dense_init(ks[1], d, d, cfg.jdtype),
        "wv": dense_init(ks[2], d, d, cfg.jdtype),
        "wif": dense_init(ks[3], d, 2 * h, cfg.jdtype),     # input & forget gates
        "wo_gate": dense_init(ks[4], d, d, cfg.jdtype),
        "wout": dense_init(ks[5], d, d, cfg.jdtype,
                           scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _mlstm_chunk_scan(q, k, v, logf, logi, chunk: int):
    """q,k,v: (B,H,T,hd); logf,logi: (B,H,T). Chunkwise-parallel mLSTM.

    Within-chunk: decay matrix D_ij = exp(F_i - F_j + logi_j) for j <= i
    (F = cumsum logf within chunk), applied attention-style.
    Across chunks: carry (C, n, m) state. Stabilized with running max m.
    """
    b, h, t, hd = q.shape
    nc = t // chunk
    qc = q.reshape(b, h, nc, chunk, hd)
    kc = k.reshape(b, h, nc, chunk, hd)
    vc = v.reshape(b, h, nc, chunk, hd)
    fc = logf.reshape(b, h, nc, chunk)
    ic = logi.reshape(b, h, nc, chunk)

    fcum = jnp.cumsum(fc, axis=-1)                         # within-chunk cumsum
    fsum = fcum[..., -1]                                   # total chunk decay

    def step(carry, inputs):
        c_state, n_state, m_state = carry                  # (B,H,hd,hd), (B,H,hd), (B,H)
        qi, ki, vi, fcu, icu, fs = inputs                  # per-chunk slices

        # log weights for contributions of in-chunk position j to i
        # intra: a_ij = fcu_i - fcu_j + icu_j  (j <= i)
        intra = fcu[..., :, None] - fcu[..., None, :] + icu[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        intra = jnp.where(tri, intra, -jnp.inf)
        # inter: state contribution carries log-magnitude m_state + fcu_i
        inter_log = fcu + m_state[..., None]               # (B,H,L)

        m_new = jnp.maximum(jnp.max(intra, axis=-1), inter_log)   # (B,H,L)
        m_new = jnp.maximum(m_new, -1e30)

        w_intra = jnp.exp(intra - m_new[..., None])        # (B,H,L,L)
        w_inter = jnp.exp(inter_log - m_new)               # (B,H,L)

        scores = jnp.einsum("bhid,bhjd->bhij", qi, ki) / jnp.sqrt(float(hd))
        y_intra = jnp.einsum("bhij,bhij,bhjd->bhid", scores, w_intra, vi)
        y_inter = w_inter[..., None] * jnp.einsum("bhid,bhde->bhie", qi, c_state) \
            / jnp.sqrt(float(hd))
        # normalizer: n^T q with same weights
        qn_intra = jnp.einsum("bhij,bhij->bhi",
                              jnp.einsum("bhid,bhjd->bhij", qi, ki) / jnp.sqrt(float(hd)),
                              w_intra)
        qn_inter = w_inter * jnp.einsum("bhid,bhd->bhi", qi, n_state) / jnp.sqrt(float(hd))
        denom = jnp.maximum(jnp.abs(qn_intra + qn_inter), jnp.exp(-m_new))
        y = (y_intra + y_inter) / denom[..., None]

        # carry state to next chunk: C' = exp(fs) C + sum_j exp(fsum - fcu_j + icu_j) v_j k_j^T
        carry_log = fs[..., None] - fcu + icu              # (B,H,L)
        m_carry = jnp.maximum(fs + m_state, jnp.max(carry_log, axis=-1))
        w_carry = jnp.exp(carry_log - m_carry[..., None])  # (B,H,L)
        c_new = jnp.exp(fs + m_state - m_carry)[..., None, None] * c_state \
            + jnp.einsum("bhj,bhjd,bhje->bhde", w_carry, ki, vi)
        n_new = jnp.exp(fs + m_state - m_carry)[..., None] * n_state \
            + jnp.einsum("bhj,bhjd->bhd", w_carry, ki)
        return (c_new, n_new, m_carry), y

    init = (
        jnp.zeros((b, h, hd, hd), jnp.float32),
        jnp.zeros((b, h, hd), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    xs = (
        qc.transpose(2, 0, 1, 3, 4), kc.transpose(2, 0, 1, 3, 4),
        vc.transpose(2, 0, 1, 3, 4), fcum.transpose(2, 0, 1, 3),
        ic.transpose(2, 0, 1, 3), fsum.transpose(2, 0, 1),
    )
    _, ys = jax.lax.scan(step, init, xs)
    y = ys.transpose(1, 2, 0, 3, 4).reshape(b, h, t, hd)
    return y


def mlstm_forward(p, x, cfg: ModelConfig):
    b, t, d = x.shape
    h = cfg.n_heads
    hd = d // h
    chunk = min(cfg.xlstm.chunk, t)
    # pad T to a multiple of chunk
    pad = (-t) % chunk
    xp = jnp.pad(x, ((0, 0), (0, pad), (0, 0))) if pad else x
    tp = t + pad

    q = (xp @ p["wq"]).reshape(b, tp, h, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    k = (xp @ p["wk"]).reshape(b, tp, h, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    v = (xp @ p["wv"]).reshape(b, tp, h, hd).transpose(0, 2, 1, 3).astype(jnp.float32)
    gates = (xp @ p["wif"]).astype(jnp.float32).reshape(b, tp, h, 2).transpose(0, 2, 1, 3)
    logi = gates[..., 0]                                   # pre-activation input gate (log space)
    logf = jax.nn.log_sigmoid(gates[..., 1])               # forget in (0,1), log space

    y = _mlstm_chunk_scan(q, k, v, logf, logi, chunk)
    y = y.transpose(0, 2, 1, 3).reshape(b, tp, d)[:, :t]
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    return shard_act(((y.astype(x.dtype)) * o) @ p["wout"], "btd")


def mlstm_init_cache(cfg: ModelConfig, batch: int):
    h = cfg.n_heads
    hd = cfg.d_model // h
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
    }


def mlstm_decode(p, x, cache, cfg: ModelConfig):
    b, _, d = x.shape
    h = cfg.n_heads
    hd = d // h
    q = (x @ p["wq"]).reshape(b, h, hd).astype(jnp.float32)
    k = (x @ p["wk"]).reshape(b, h, hd).astype(jnp.float32)
    v = (x @ p["wv"]).reshape(b, h, hd).astype(jnp.float32)
    gates = (x @ p["wif"]).astype(jnp.float32).reshape(b, h, 2)
    logi = gates[..., 0]
    logf = jax.nn.log_sigmoid(gates[..., 1])

    m_new = jnp.maximum(logf + cache["m"], logi)
    c = jnp.exp(logf + cache["m"] - m_new)[..., None, None] * cache["c"] \
        + jnp.exp(logi - m_new)[..., None, None] * jnp.einsum("bhd,bhe->bhde", k, v)
    n = jnp.exp(logf + cache["m"] - m_new)[..., None] * cache["n"] \
        + jnp.exp(logi - m_new)[..., None] * k

    qn = jnp.einsum("bhd,bhd->bh", q, n) / jnp.sqrt(float(hd))
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_new))
    y = jnp.einsum("bhd,bhde->bhe", q, c) / jnp.sqrt(float(hd)) / denom[..., None]
    y = y.reshape(b, 1, d)
    o = jax.nn.sigmoid(x @ p["wo_gate"])
    out = (y.astype(x.dtype) * o) @ p["wout"]
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    ks = jax.random.split(key, 3)
    return {
        "wx": dense_init(ks[0], d, 4 * d, cfg.jdtype),      # i, f, z, o pre-acts
        "wr": dense_init(ks[1], d, 4 * d, cfg.jdtype, scale=0.5),  # recurrent
        "b": jnp.zeros((4 * d,), jnp.float32),
        "wout": dense_init(ks[2], d, d, cfg.jdtype,
                           scale=1.0 / (2 * cfg.n_layers) ** 0.5),
    }


def _slstm_cell(carry, pre):
    """carry = (c, n, h, m); pre = x-projection at t (B, 4d) fp32."""
    c, n, h, m = carry
    zi, zf, zz, zo = jnp.split(pre, 4, axis=-1)
    logi = zi                                               # exp input gate (log)
    logf = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(logf + m, logi)
    i_g = jnp.exp(logi - m_new)
    f_g = jnp.exp(logf + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f_g * c + i_g * z
    n_new = f_g * n + i_g
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(p, x, cfg: ModelConfig):
    b, t, d = x.shape
    xs = (x @ p["wx"]).astype(jnp.float32) + p["b"]

    def step(carry, xt):
        # recurrent contribution from h_{t-1}
        c, n, h, m = carry
        pre = xt + (h.astype(x.dtype) @ p["wr"]).astype(jnp.float32)
        return _slstm_cell((c, n, h, m), pre)

    init = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
            jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30, jnp.float32))
    _, hs = jax.lax.scan(step, init, xs.transpose(1, 0, 2))
    y = hs.transpose(1, 0, 2).astype(x.dtype)
    return shard_act(y @ p["wout"], "btd")


def slstm_init_cache(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    z = lambda: jnp.zeros((batch, d), jnp.float32)
    return {"c": z(), "n": z(), "h": z(), "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_decode(p, x, cache, cfg: ModelConfig):
    b, _, d = x.shape
    pre = (x[:, 0] @ p["wx"]).astype(jnp.float32) + p["b"] \
        + (cache["h"].astype(x.dtype) @ p["wr"]).astype(jnp.float32)
    (c, n, h, m), hnew = _slstm_cell(
        (cache["c"], cache["n"], cache["h"], cache["m"]), pre)
    y = (hnew.astype(x.dtype) @ p["wout"])[:, None]
    return y, {"c": c, "n": n, "h": h, "m": m}
