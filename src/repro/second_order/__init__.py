from .optim import adamw, sgd, OptState
from .fednl_precond import FedNLPrecondOptimizer, fednl_precond
