from .fednl_precond import (
    FedNLPrecondOptimizer,
    FedNLPrecondState,
    fednl_precond,
)
from .optim import Optimizer, OptState, adamw, apply_updates, sgd
