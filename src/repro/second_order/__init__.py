from .fednl_precond import FedNLPrecondOptimizer, fednl_precond
from .optim import OptState, adamw, sgd
