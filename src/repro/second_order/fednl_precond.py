"""FedNL curvature learning at LLM scale (beyond-paper adaptation).

The paper's full d x d Hessian is infeasible for d >= 1e6, but its core
mechanism — learn a curvature estimate H via compressed differences

    H^{k+1} = H^k + alpha * C(D^k - H^k),        C contractive,

with the l^k = ||D^k - H^k||_F correction making H + l I a safe
preconditioner (Option 2) — applies verbatim to *structured* curvature.
Here H is per-parameter-tensor **diagonal** curvature, D^k is a local
curvature observation:

  * 'fisher'     — minibatch empirical Fisher diagonal, D = E[g^2]
  * 'hutchinson' — Hutchinson diagonal estimate z * (Hess z) via one
                   extra HVP per step (true GGN curvature)

and C is Block-TopK over the (2D-reshaped) tensor — the same operator
class (delta = k_b/b^2) the core library proves rates for, and the same
Pallas kernel the TPU path uses.

Placement of compression: in cross-silo deployment each silo compresses
its D_i^k before uplink (the paper's accounting); inside a single pod the
data-parallel all-reduce is dense, so the compressed learning rule is
applied to the aggregated D^k. The contraction argument (Lemma B.1 with
y = aggregated observation) is unchanged; DESIGN.md §3 records this
deviation. Both placements speak the payload wire format end to end:
compression goes through the FUSED diff payload op
(``kernels/block_topk.diff_topk_payload`` — the Pallas kernel on TPU,
the sort-based jnp oracle elsewhere): D = obs - H is formed tile-wise
in VMEM, selected, and emitted as payload arrays in one pass, with
||D||_F^2 accumulated from the same tiles, so the dense difference
never round-trips HBM and the l^k norm costs no extra reduction. The
dense H increment is reconstructed through the payload-space scatter
(``kernels/scatter_accum.block_scatter_accumulate``), so the training
step materializes neither a dense (nblocks, block^2) selection mask nor
a per-silo dense decompression round-trip. When ``observations`` carry
a leading silo axis (one observation per silo — the paper's placement)
each silo compresses its own diff and H is updated from the server-side
payload-space mean — the same aggregation subsystem the core methods
use.

Update rule per tensor (Option-2 Newton-type step, diagonal solve):

    l^k   = ||D^k - H^k||_F / sqrt(numel)        (scale-matched ridge)
    u     = -lr * g / (sqrt(max(H^k, 0)) + sqrt(l^k) + eps)
    H^{k+1} = H^k + alpha * C(D^k - H^k)

The sqrt denominator is deliberate (pinned by tests/test_infra.py):
H tracks *squared*-gradient curvature (Fisher / Hutchinson-GGN), so
sqrt(H) is the gradient's natural scale — the Adam/AdaGrad-consistent
diagonal Newton step — and the ridge enters as sqrt(l) so both terms
live in the same units.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import BlockSparsePayload, BlockTopK, BlockTopKThreshold
from repro.kernels.block_topk import block_topk_payload, diff_topk_payload

from .optim import Optimizer


class FedNLPrecondState(NamedTuple):
    step: jax.Array
    h: Any            # per-tensor diagonal curvature estimates (fp32)
    mu: Any           # momentum on the preconditioned step
    l: Any = ()       # per-tensor Option-2 ridge from the last refresh


def _shape2d(shape) -> tuple:
    """Block-partition layout of a tensor: collapse every leading axis
    onto the rows so a stacked per-layer param (n_seg, din, dout) tiles
    as (n_seg * din, dout) — each layer's rows land in their own block
    rows instead of one long smeared row per segment."""
    if len(shape) == 0:
        return (1, 1)
    if len(shape) == 1:
        return (1, shape[0])
    rows = 1
    for s in shape[:-1]:
        rows *= s
    return (rows, shape[-1])


def _as2d(x: jax.Array) -> jax.Array:
    return x.reshape(_shape2d(x.shape))


@dataclasses.dataclass(frozen=True)
class FedNLPrecondOptimizer:
    lr: float = 1e-3
    alpha: float = 1.0                 # Hessian learning rate (Assumption 3.4(ii))
    k_per_block: int = 2048            # Block-TopK sparsity (delta = k/b^2)
    block: int = 128
    momentum: float = 0.9
    eps: float = 1e-8
    weight_decay: float = 0.0
    curvature: str = "fisher"          # fisher | hutchinson
    selector: str = "threshold"        # threshold (bisection) | sort
    use_pallas: Optional[bool] = None  # None = auto (Pallas ops on TPU)

    def _k(self) -> int:
        return min(self.k_per_block, self.block * self.block)

    @property
    def compressor(self):
        """The Block-TopK codec — the analytic Def 3.3 operator
        (``spec``/delta accounting and the aggregate reference).
        ``update`` itself routes compression through the payload op,
        whose selection matches ``threshold`` (bisection, the Pallas
        kernel) on TPU and ``sort`` (jax.lax.top_k) elsewhere — the two
        differ only inside bisection-resolution tie clusters."""
        if self.selector == "threshold":
            # §Perf pair 3: bisection selection (the Pallas kernel's
            # algorithm) instead of a per-tile sort inside every step.
            return BlockTopKThreshold(k_per_block=self._k(), block=self.block)
        return BlockTopK(k_per_block=self._k(), block=self.block)

    def init(self, params) -> FedNLPrecondState:
        z32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return FedNLPrecondState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(z32, params),
            jax.tree.map(z32, params),
            jax.tree.map(lambda p: jnp.zeros((), jnp.float32), params),
        )

    def observe(self, grads, params=None, hvp=None):
        """Local curvature observation D^k per tensor."""
        if self.curvature == "hutchinson":
            if hvp is None:
                raise ValueError(
                    "curvature='hutchinson' requires the hvp=(z, Hz) "
                    "probe (one Hessian-vector product per step); got "
                    "hvp=None — refusing to silently fall back to the "
                    "Fisher diagonal")
            # hutchinson: caller supplies hvp = Hessian @ z and the probe z
            z, hz = hvp
            return jax.tree.map(
                lambda zz, hh: (zz.astype(jnp.float32)
                                * hh.astype(jnp.float32)), z, hz)
        return jax.tree.map(lambda g: g.astype(jnp.float32) ** 2, grads)

    def _compress_payload(self, x2d: jax.Array):
        """Device-side compress of one 2D diff into the
        BlockSparsePayload arrays via the payload-emitting op: the step
        never materializes a dense (nblocks, block^2) selection mask on
        the Pallas path."""
        return block_topk_payload(x2d, k=self._k(), block=self.block,
                                  use_pallas=self.use_pallas)

    def _diff_payload(self, a2d: jax.Array, b2d: jax.Array):
        """Fused diff -> select -> payload of D = a2d - b2d plus the
        Frobenius sum-of-squares of D, one pass: on the Pallas path the
        dense (d, d) difference lives only in VMEM tiles — it never
        round-trips HBM — and ||D||_F comes free from the same tiles."""
        return diff_topk_payload(a2d, b2d, k=self._k(), block=self.block,
                                 use_pallas=self.use_pallas)

    def _payload_mean(self, vals: jax.Array, idx: jax.Array, shape2):
        """Dense mean of n stacked per-silo payloads through the one
        payload-space aggregation (``_BlockSparse.aggregate`` — the
        tiled-by-construction block scatter kernel on TPU): no per-silo
        dense decompression, ONE accumulator."""
        payloads = BlockSparsePayload(values=vals, indices=idx,
                                      universe=self.block * self.block)
        return self.compressor.aggregate(payloads, tuple(shape2),
                                         use_pallas=self.use_pallas)

    def _learn_tensor(self, h, d_obs):
        """One tensor's compressed Hessian learning: the payload-space
        increment s = C(D^k - H^k) (or the server mean of per-silo
        payloads when ``d_obs`` carries a leading silo axis) plus the
        scale-matched Option-2 ridge l^k. Returns (s, l)."""
        h2 = _as2d(h)
        if d_obs.ndim == h.ndim + 1:
            # cross-silo: per-silo payloads, ONE dense accumulator.
            # Each silo runs the fused diff kernel against the same
            # shared H — the per-silo dense diff never materializes.
            obs2 = d_obs.astype(jnp.float32).reshape(
                (d_obs.shape[0],) + h2.shape)
            vals, idx, sq = jax.vmap(
                lambda a: self._diff_payload(a, h2))(obs2)
            s = self._payload_mean(vals, idx, h2.shape).reshape(h.shape)
            # l^k = mean_i ||D_i - H||_F, scale-matched (Option 2)
            l = jnp.mean(jnp.sqrt(sq / h.size + 1e-30))
        else:
            # the uplink object is the payload; H learns from it.
            # Fused: D = obs - H is formed tile-wise inside the
            # payload kernel, and sq = ||D||_F^2 rides along.
            vals, idx, sq = self._diff_payload(_as2d(d_obs), h2)
            s = self._payload_mean(vals[None], idx[None],
                                   h2.shape).reshape(h.shape)
            # l^k correction (Option 2), scale-matched to the diagonal
            l = jnp.sqrt(sq / h.size + 1e-30)
        return s, l

    def _precond_tensor(self, g, h, m, p, l):
        """The cheap per-step preconditioned update from stored (h, l)."""
        g32 = g.astype(jnp.float32)
        denom = jnp.sqrt(jnp.maximum(h, 0.0)) + jnp.sqrt(l) + self.eps
        step = g32 / denom
        if self.weight_decay:
            step = step + self.weight_decay * p.astype(jnp.float32)
        m_new = self.momentum * m + step
        u = (-self.lr * m_new).astype(p.dtype)
        return u, m_new

    @staticmethod
    def _pick(out, i):
        return jax.tree.map(lambda t: t[i], out,
                            is_leaf=lambda t: isinstance(t, tuple))

    def refresh(self, state: FedNLPrecondState, observations
                ) -> FedNLPrecondState:
        """Learn curvature from (possibly silo-stacked) observations —
        the expensive, uplink-bearing phase. Updates ``h`` and the
        stored ridge ``l``; ``step``/``mu`` are untouched, so the train
        step can run this under ``lax.cond`` every ``refresh_every``
        steps and ``precondition`` every step."""
        out = jax.tree.map(self._learn_tensor, state.h, observations)
        s, l = self._pick(out, 0), self._pick(out, 1)
        h_new = jax.tree.map(lambda h, si: h + self.alpha * si, state.h, s)
        return state._replace(h=h_new, l=l)

    def precondition(self, grads, state: FedNLPrecondState, params):
        """Preconditioned step from the curvature stored by the last
        ``refresh`` (h AND its matching l — unlike legacy ``update``,
        which blends the pre-learning h with the current obs l)."""
        unset = isinstance(state.l, tuple) and len(state.l) == 0
        l = jax.tree.map(lambda h: jnp.zeros((), jnp.float32),
                         state.h) if unset else state.l
        out = jax.tree.map(self._precond_tensor, grads, state.h, state.mu,
                           params, l)
        return self._pick(out, 0), state._replace(
            step=state.step + 1, mu=self._pick(out, 1))

    def uplink_bits(self, params, n_silos: int = 1) -> int:
        """Host-side wire cost of ONE curvature refresh: every silo
        ships one Block-TopK diff payload per parameter tensor
        (``wire_cost`` analytic accounting — k values + k indices per
        block on the 2D block partition). Call at setup time, not
        inside the jitted step."""
        from repro.wire import wire_cost

        total = 0
        for p in jax.tree.leaves(params):
            rep = wire_cost(self.compressor, _shape2d(p.shape),
                            encoded=False)
            total += int(rep.analytic_bits)
        return total * int(n_silos)

    def update(self, grads, state: FedNLPrecondState, params,
               observations=None):
        """``observations`` leaves may carry a leading silo axis (ndim ==
        param.ndim + 1): then each silo's diff is compressed on-device
        and H learns from the payload-space server mean.

        This is the fused learn-and-step path (curvature every step);
        the amortized train-step path is ``refresh`` + ``precondition``.
        Pinned semantics: the denominator uses the PRE-learning h with
        the CURRENT observation's l."""

        obs = observations if observations is not None else self.observe(grads)

        def per_tensor(g, h, m, p, d_obs):
            s, l = self._learn_tensor(h, d_obs)
            u, m_new = self._precond_tensor(g, h, m, p, l)
            h_new = h + self.alpha * s
            return u, h_new, m_new, l

        out = jax.tree.map(per_tensor, grads, state.h, state.mu, params, obs)
        return self._pick(out, 0), FedNLPrecondState(
            state.step + 1, self._pick(out, 1), self._pick(out, 2),
            self._pick(out, 3))


def fednl_precond(lr: float = 1e-3, **kw) -> Optimizer:
    """Adapter matching the Optimizer(init, update) protocol. ``update``
    is bound directly (NOT wrapped in a 3-arg lambda) so the optional
    ``observations`` 4th argument — the cross-silo payload path —
    reaches the optimizer through the protocol; the amortized
    second-order hooks (observe / refresh / precondition) and the
    host-side uplink accounting are bound alongside so
    ``make_train_step`` can drive the refresh-interval path."""
    opt = FedNLPrecondOptimizer(lr=lr, **kw)
    return Optimizer(opt.init, opt.update, observe=opt.observe,
                     refresh=opt.refresh, precondition=opt.precondition,
                     uplink_bits=opt.uplink_bits)
