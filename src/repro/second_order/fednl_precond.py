"""FedNL curvature learning at LLM scale (beyond-paper adaptation).

The paper's full d x d Hessian is infeasible for d >= 1e6, but its core
mechanism — learn a curvature estimate H via compressed differences

    H^{k+1} = H^k + alpha * C(D^k - H^k),        C contractive,

with the l^k = ||D^k - H^k||_F correction making H + l I a safe
preconditioner (Option 2) — applies verbatim to *structured* curvature.
Here H is per-parameter-tensor **diagonal** curvature, D^k is a local
curvature observation:

  * 'fisher'     — minibatch empirical Fisher diagonal, D = E[g^2]
  * 'hutchinson' — Hutchinson diagonal estimate z * (Hess z) via one
                   extra HVP per step (true GGN curvature)

and C is Block-TopK over the (2D-reshaped) tensor — the same operator
class (delta = k_b/b^2) the core library proves rates for, and the same
Pallas kernel the TPU path uses.

Placement of compression: in cross-silo deployment each silo compresses
its D_i^k before uplink (the paper's accounting); inside a single pod the
data-parallel all-reduce is dense, so the compressed learning rule is
applied to the aggregated D^k. The contraction argument (Lemma B.1 with
y = aggregated observation) is unchanged; DESIGN.md §3 records this
deviation. Both placements speak the payload wire format end to end:
compression goes through the FUSED diff payload op
(``kernels/block_topk.diff_topk_payload`` — the Pallas kernel on TPU,
the sort-based jnp oracle elsewhere): D = obs - H is formed tile-wise
in VMEM, selected, and emitted as payload arrays in one pass, with
||D||_F^2 accumulated from the same tiles, so the dense difference
never round-trips HBM and the l^k norm costs no extra reduction. The
dense H increment is reconstructed through the payload-space scatter
(``kernels/scatter_accum.block_scatter_accumulate``), so the training
step materializes neither a dense (nblocks, block^2) selection mask nor
a per-silo dense decompression round-trip. When ``observations`` carry
a leading silo axis (one observation per silo — the paper's placement)
each silo compresses its own diff and H is updated from the server-side
payload-space mean — the same aggregation subsystem the core methods
use.

Update rule per tensor (Option-2 Newton-type step, diagonal solve):

    l^k   = ||D^k - H^k||_F / sqrt(numel)        (scale-matched ridge)
    u     = -lr * g / (sqrt(max(H^k, 0)) + sqrt(l^k) + eps)
    H^{k+1} = H^k + alpha * C(D^k - H^k)

The sqrt denominator is deliberate (pinned by tests/test_infra.py):
H tracks *squared*-gradient curvature (Fisher / Hutchinson-GGN), so
sqrt(H) is the gradient's natural scale — the Adam/AdaGrad-consistent
diagonal Newton step — and the ridge enters as sqrt(l) so both terms
live in the same units.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.compressors import BlockSparsePayload, BlockTopK, BlockTopKThreshold
from repro.kernels.block_topk import block_topk_payload, diff_topk_payload

from .optim import Optimizer


class FedNLPrecondState(NamedTuple):
    step: jax.Array
    h: Any            # per-tensor diagonal curvature estimates (fp32)
    mu: Any           # momentum on the preconditioned step


def _as2d(x: jax.Array) -> jax.Array:
    if x.ndim == 0:
        return x.reshape(1, 1)
    if x.ndim == 1:
        return x.reshape(1, -1)
    return x.reshape(x.shape[0], -1)


@dataclasses.dataclass(frozen=True)
class FedNLPrecondOptimizer:
    lr: float = 1e-3
    alpha: float = 1.0                 # Hessian learning rate (Assumption 3.4(ii))
    k_per_block: int = 2048            # Block-TopK sparsity (delta = k/b^2)
    block: int = 128
    momentum: float = 0.9
    eps: float = 1e-8
    weight_decay: float = 0.0
    curvature: str = "fisher"          # fisher | hutchinson
    selector: str = "threshold"        # threshold (bisection) | sort
    use_pallas: Optional[bool] = None  # None = auto (Pallas ops on TPU)

    def _k(self) -> int:
        return min(self.k_per_block, self.block * self.block)

    @property
    def compressor(self):
        """The Block-TopK codec — the analytic Def 3.3 operator
        (``spec``/delta accounting and the aggregate reference).
        ``update`` itself routes compression through the payload op,
        whose selection matches ``threshold`` (bisection, the Pallas
        kernel) on TPU and ``sort`` (jax.lax.top_k) elsewhere — the two
        differ only inside bisection-resolution tie clusters."""
        if self.selector == "threshold":
            # §Perf pair 3: bisection selection (the Pallas kernel's
            # algorithm) instead of a per-tile sort inside every step.
            return BlockTopKThreshold(k_per_block=self._k(), block=self.block)
        return BlockTopK(k_per_block=self._k(), block=self.block)

    def init(self, params) -> FedNLPrecondState:
        z32 = lambda p: jnp.zeros(p.shape, jnp.float32)
        return FedNLPrecondState(
            jnp.zeros((), jnp.int32),
            jax.tree.map(z32, params),
            jax.tree.map(z32, params),
        )

    def observe(self, grads, params=None, hvp=None):
        """Local curvature observation D^k per tensor."""
        if self.curvature == "hutchinson":
            if hvp is None:
                raise ValueError(
                    "curvature='hutchinson' requires the hvp=(z, Hz) "
                    "probe (one Hessian-vector product per step); got "
                    "hvp=None — refusing to silently fall back to the "
                    "Fisher diagonal")
            # hutchinson: caller supplies hvp = Hessian @ z and the probe z
            z, hz = hvp
            return jax.tree.map(
                lambda zz, hh: (zz.astype(jnp.float32)
                                * hh.astype(jnp.float32)), z, hz)
        return jax.tree.map(lambda g: g.astype(jnp.float32) ** 2, grads)

    def _compress_payload(self, x2d: jax.Array):
        """Device-side compress of one 2D diff into the
        BlockSparsePayload arrays via the payload-emitting op: the step
        never materializes a dense (nblocks, block^2) selection mask on
        the Pallas path."""
        return block_topk_payload(x2d, k=self._k(), block=self.block,
                                  use_pallas=self.use_pallas)

    def _diff_payload(self, a2d: jax.Array, b2d: jax.Array):
        """Fused diff -> select -> payload of D = a2d - b2d plus the
        Frobenius sum-of-squares of D, one pass: on the Pallas path the
        dense (d, d) difference lives only in VMEM tiles — it never
        round-trips HBM — and ||D||_F comes free from the same tiles."""
        return diff_topk_payload(a2d, b2d, k=self._k(), block=self.block,
                                 use_pallas=self.use_pallas)

    def _payload_mean(self, vals: jax.Array, idx: jax.Array, shape2):
        """Dense mean of n stacked per-silo payloads through the one
        payload-space aggregation (``_BlockSparse.aggregate`` — the
        tiled-by-construction block scatter kernel on TPU): no per-silo
        dense decompression, ONE accumulator."""
        payloads = BlockSparsePayload(values=vals, indices=idx,
                                      universe=self.block * self.block)
        return self.compressor.aggregate(payloads, tuple(shape2),
                                         use_pallas=self.use_pallas)

    def update(self, grads, state: FedNLPrecondState, params,
               observations=None):
        """``observations`` leaves may carry a leading silo axis (ndim ==
        param.ndim + 1): then each silo's diff is compressed on-device
        and H learns from the payload-space server mean."""

        obs = observations if observations is not None else self.observe(grads)

        def per_tensor(g, h, m, p, d_obs):
            g32 = g.astype(jnp.float32)
            h2 = _as2d(h)
            if d_obs.ndim == h.ndim + 1:
                # cross-silo: per-silo payloads, ONE dense accumulator.
                # Each silo runs the fused diff kernel against the same
                # shared H — the per-silo dense diff never materializes.
                obs2 = d_obs.astype(jnp.float32).reshape(
                    (d_obs.shape[0],) + h2.shape)
                vals, idx, sq = jax.vmap(
                    lambda a: self._diff_payload(a, h2))(obs2)
                s = self._payload_mean(vals, idx, h2.shape).reshape(h.shape)
                # l^k = mean_i ||D_i - H||_F, scale-matched (Option 2)
                l = jnp.mean(jnp.sqrt(sq / h.size + 1e-30))
            else:
                # the uplink object is the payload; H learns from it.
                # Fused: D = obs - H is formed tile-wise inside the
                # payload kernel, and sq = ||D||_F^2 rides along.
                vals, idx, sq = self._diff_payload(_as2d(d_obs), h2)
                s = self._payload_mean(vals[None], idx[None],
                                       h2.shape).reshape(h.shape)
                # l^k correction (Option 2), scale-matched to the diagonal
                l = jnp.sqrt(sq / h.size + 1e-30)
            denom = jnp.sqrt(jnp.maximum(h, 0.0)) + jnp.sqrt(l) + self.eps
            step = g32 / denom
            if self.weight_decay:
                step = step + self.weight_decay * p.astype(jnp.float32)
            m_new = self.momentum * m + step
            u = (-self.lr * m_new).astype(p.dtype)
            h_new = h + self.alpha * s
            return u, h_new, m_new

        out = jax.tree.map(per_tensor, grads, state.h, state.mu, params, obs)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda t: isinstance(t, tuple))
        return pick(0), FedNLPrecondState(state.step + 1, pick(1), pick(2))


def fednl_precond(lr: float = 1e-3, **kw) -> Optimizer:
    """Adapter matching the Optimizer(init, update) protocol. ``update``
    is bound directly (NOT wrapped in a 3-arg lambda) so the optional
    ``observations`` 4th argument — the cross-silo payload path —
    reaches the optimizer through the protocol."""
    opt = FedNLPrecondOptimizer(lr=lr, **kw)
    return Optimizer(opt.init, opt.update)
