"""First-order optimizer substrate (written from scratch; no optax).

Functional API mirroring the usual gradient-transform style:

    opt = adamw(lr=3e-4)
    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)

Moments are stored in the dtype of the parameters by default; pass
``moment_dtype`` to override (bf16 moments keep the 314B/398B configs
inside v5e HBM at 512 chips — see DESIGN.md §5).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class OptState(NamedTuple):
    step: jax.Array
    mu: Any          # first moment (or momentum), pytree or ()
    nu: Any          # second moment, pytree or ()


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """``update`` is ``(grads, state, params, observations=None) ->
    (updates, state)``. The optional 4th argument carries per-silo
    curvature observations for optimizers that learn from them
    (``second_order/fednl_precond`` — a leading silo axis routes the
    cross-silo payload-aggregation path); first-order optimizers accept
    and ignore it, and plain 3-arg calls keep working everywhere.

    Second-order optimizers additionally expose the amortized protocol
    (all three hooks or none — ``make_train_step`` keys on ``refresh``):

      ``observe(grads, params=None, hvp=None) -> obs``  local curvature
          observation D^k per tensor (no state touched);
      ``refresh(state, observations) -> state``  learn curvature from
          (possibly silo-stacked) observations — the expensive phase,
          run every ``refresh_every`` steps under ``lax.cond``;
      ``precondition(grads, state, params) -> (updates, state)``  the
          cheap per-step preconditioned update from stored curvature.

    ``uplink_bits(params, n_silos=1) -> int`` is host-side wire-cost
    accounting for ONE refresh (what each silo ships), for logging."""

    init: Callable
    update: Callable
    observe: Optional[Callable] = None
    refresh: Optional[Callable] = None
    precondition: Optional[Callable] = None
    uplink_bits: Optional[Callable] = None


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u).astype(p.dtype), params, updates)


def sgd(lr: float, momentum: float = 0.0, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        mu = jax.tree.map(jnp.zeros_like, params) if momentum else ()
        return OptState(jnp.zeros((), jnp.int32), mu, ())

    def update(grads, state, params, observations=None):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g, state.mu, grads)
            upd = jax.tree.map(lambda m: -lr * m, mu)
        else:
            mu = ()
            upd = jax.tree.map(lambda g: -lr * g, grads)
        return upd, OptState(state.step + 1, mu, ())

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          moment_dtype: Optional[jnp.dtype] = None) -> Optimizer:
    def init(params):
        def z(p):
            dt = moment_dtype or p.dtype
            return jnp.zeros(p.shape, dt)

        return OptState(jnp.zeros((), jnp.int32),
                        jax.tree.map(z, params), jax.tree.map(z, params))

    def update(grads, state, params, observations=None):
        step = state.step + 1
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(g, m, v, p):
            g32 = g.astype(jnp.float32)
            m_new = b1 * m.astype(jnp.float32) + (1 - b1) * g32
            v_new = b2 * v.astype(jnp.float32) + (1 - b2) * g32 * g32
            mhat = m_new / c1
            vhat = v_new / c2
            u = -lr * (mhat / (jnp.sqrt(vhat) + eps)
                       + weight_decay * p.astype(jnp.float32))
            return u.astype(p.dtype), m_new.astype(m.dtype), v_new.astype(v.dtype)

        out = jax.tree.map(upd, grads, state.mu, state.nu, params)
        upds = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
        mus = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
        nus = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
        return upds, OptState(step, mus, nus)

    return Optimizer(init, update)
