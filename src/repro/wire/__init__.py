"""repro.wire — bytes on a wire, and what they cost.

The host-side bitstream codec for every registered payload family
(``codec``: Golomb–Rice delta-coded index streams, raw/fp16/int8 value
streams, bit-exact fp32/fp64 round trips), the traffic model that turns
encoded bytes into simulated seconds per round (``traffic``), and the
unified ``WireReport`` cost surface (``report.wire_cost``) that
supersedes the scattered bits accessors. See each submodule's docstring
for the wire format and the model; ``ROADMAP.md`` item 2 is the design
brief.
"""

from .bitio import BitReader, BitWriter, best_rice_param
from .codec import (
    VALUE_FORMATS,
    WireFormatError,
    canonical,
    decode,
    encode,
    encode_silos,
    encoded_bytes,
)
from .report import WireReport, silo_encoded_bytes, wire_cost
from .traffic import (
    PRESETS,
    LinkModel,
    link_model,
    round_seconds,
    seconds_curve,
    transfer_seconds,
)

__all__ = [
    "BitReader", "BitWriter", "best_rice_param",
    "VALUE_FORMATS", "WireFormatError", "canonical", "decode", "encode",
    "encode_silos", "encoded_bytes",
    "WireReport", "silo_encoded_bytes", "wire_cost",
    "PRESETS", "LinkModel", "link_model", "round_seconds", "seconds_curve",
    "transfer_seconds",
]
