"""Bit-granular I/O and Golomb–Rice coding — the primitives under the
wire codec (``repro.wire.codec``).

Everything here is host-side numpy/bytes: the codec runs at the jax
payload boundary, after device arrays have been pulled to the host, so
no op in this module needs to be jittable. ``BitWriter``/``BitReader``
are MSB-first within each byte (the conventional bitstream order), and
the Golomb–Rice coder is the classic unary-quotient + ``r``-bit
remainder code: a non-negative symbol ``v`` costs ``(v >> r) + 1 + r``
bits. ``best_rice_param`` picks ``r`` by exhaustive exact cost over a
small candidate range (vectorized — the cost of Rice coding is linear
in the symbols either way), so the index streams the codec emits are
within one header byte of the best this code family can do.

Signed symbols (unsorted index deltas) go through zigzag mapping
(0, -1, 1, -2, ... -> 0, 1, 2, 3, ...) so small magnitudes of either
sign stay cheap.
"""

from __future__ import annotations

import numpy as np

_MAX_RICE_PARAM = 30


class BitWriter:
    """Append-only MSB-first bit buffer backed by a Python int window."""

    def __init__(self):
        self._chunks = bytearray()
        self._acc = 0       # pending bits, MSB-first
        self._nbits = 0     # number of pending bits in _acc

    def write(self, value: int, nbits: int) -> None:
        """Append the low ``nbits`` bits of ``value`` (MSB first)."""
        if nbits == 0:
            return
        if value < 0 or (value >> nbits):
            raise ValueError(f"value {value} does not fit in {nbits} bits")
        self._acc = (self._acc << nbits) | value
        self._nbits += nbits
        while self._nbits >= 8:
            self._nbits -= 8
            self._chunks.append((self._acc >> self._nbits) & 0xFF)
        self._acc &= (1 << self._nbits) - 1

    def write_unary(self, q: int) -> None:
        """``q`` one-bits then a terminating zero-bit."""
        while q >= 32:
            self.write(0xFFFFFFFF, 32)
            q -= 32
        self.write(((1 << q) - 1) << 1, q + 1)

    def write_rice(self, value: int, r: int) -> None:
        """Golomb–Rice: unary quotient ``value >> r``, then ``r``-bit
        remainder."""
        self.write_unary(int(value) >> r)
        if r:
            self.write(int(value) & ((1 << r) - 1), r)

    def getvalue(self) -> bytes:
        """Byte-align (zero padding) and return the buffer."""
        out = bytearray(self._chunks)
        if self._nbits:
            out.append((self._acc << (8 - self._nbits)) & 0xFF)
        return bytes(out)

    def __len__(self) -> int:  # bits written so far
        return 8 * len(self._chunks) + self._nbits


class BitReader:
    """MSB-first reader over a ``bytes`` buffer."""

    def __init__(self, data: bytes, start_bit: int = 0):
        self._data = data
        self._pos = start_bit

    def read(self, nbits: int) -> int:
        if nbits == 0:
            return 0
        end = self._pos + nbits
        if end > 8 * len(self._data):
            raise ValueError("bitstream underrun")
        out = 0
        pos = self._pos
        while nbits > 0:
            byte = self._data[pos >> 3]
            avail = 8 - (pos & 7)
            take = min(avail, nbits)
            shift = avail - take
            out = (out << take) | ((byte >> shift) & ((1 << take) - 1))
            pos += take
            nbits -= take
        self._pos = pos
        return out

    def read_unary(self) -> int:
        q = 0
        while self.read(1):
            q += 1
        return q

    def read_rice(self, r: int) -> int:
        q = self.read_unary()
        return (q << r) | (self.read(r) if r else 0)

    @property
    def bit_position(self) -> int:
        return self._pos


# ---------------------------------------------------------------------------
# Golomb–Rice streams over numpy symbol arrays
# ---------------------------------------------------------------------------


def zigzag(values: np.ndarray) -> np.ndarray:
    """Signed -> unsigned: 0,-1,1,-2,... -> 0,1,2,3,... (int64 safe)."""
    v = values.astype(np.int64)
    return ((v << 1) ^ (v >> 63)).astype(np.uint64)


def unzigzag(values: np.ndarray) -> np.ndarray:
    v = values.astype(np.uint64)
    return ((v >> np.uint64(1)).astype(np.int64)
            ^ -(v & np.uint64(1)).astype(np.int64))


def best_rice_param(symbols: np.ndarray) -> int:
    """Exact-cost argmin over r in [0, 30] for non-negative symbols."""
    if symbols.size == 0:
        return 0
    s = symbols.astype(np.uint64)
    best_r, best_cost = 0, None
    for r in range(_MAX_RICE_PARAM + 1):
        cost = int(np.sum(s >> np.uint64(r))) + s.size * (r + 1)
        if best_cost is None or cost < best_cost:
            best_r, best_cost = r, cost
    return best_r


def rice_stream_bits(symbols: np.ndarray, r: int) -> int:
    """Exact bit length of the Rice stream for ``symbols`` at param ``r``."""
    if symbols.size == 0:
        return 0
    s = symbols.astype(np.uint64)
    return int(np.sum(s >> np.uint64(r))) + s.size * (r + 1)


def write_rice_stream(w: BitWriter, symbols: np.ndarray, r: int) -> None:
    for v in symbols.astype(np.uint64).tolist():
        w.write_rice(int(v), r)


def read_rice_stream(rd: BitReader, count: int, r: int) -> np.ndarray:
    out = np.empty(count, np.uint64)
    for i in range(count):
        out[i] = rd.read_rice(r)
    return out
