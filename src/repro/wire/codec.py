"""The bitstream codec: real bytes for every registered payload family.

``encode(payload) -> bytes`` / ``decode(data) -> payload`` turn the jax
payload pytrees of ``repro.core.compressors`` (SparsePayload,
BlockSparsePayload, LowRankPayload, DensePayload, DitheredPayload) into
actual byte buffers — the thing ``payload.bits()`` has only ever
*estimated*. The codec is host-side by design: it runs at the jax
payload boundary (after the device arrays are pulled to host), so the
encoded length may be data-dependent, which no jittable op could be.

Wire format (all integers little-endian / LEB128 varints, bitstreams
MSB-first and byte-aligned per section):

* **Index streams** (Sparse / BlockSparse / indexed Dense) are
  delta-coded Golomb–Rice: indices are shifted by +1 (so the ``-1``
  padding slot becomes symbol 0 and survives the round trip), sorted
  ascending (per tile for BlockSparse — value/index *pairs* move
  together, so the decoded dense matrix is unchanged), first-differenced
  and Rice-coded with an exhaustively-chosen per-stream parameter. For a
  uniform k-subset of d^2 slots this approaches the
  ``ceil(log2 C(d^2, k))`` entropy estimate that ``bits("entropy")``
  quotes. ``sort_indices=False`` keeps the payload's original pair
  order (zigzag-coded signed deltas — bigger stream, bit-exact order).
* **Value streams** ship in one of three formats: ``"raw"`` (the native
  dtype's bytes — bit-exact round trip for fp64/fp32/fp16 payloads),
  ``"fp16"`` (a float16 cast: decoded values equal
  ``orig.astype(float16).astype(orig.dtype)`` exactly, i.e. relative
  error <= 2^-11 for values in float16's normal range), and ``"int8"``
  (symmetric linear quantization with one float32 scale per stream:
  absolute error <= max|v| / 250 per entry, including the scale's own
  float32 rounding).
* **Dithered payloads** are categorical, not float: each entry packs a
  fixed-width level in [0, s] plus a 1-bit sign (2 bits when the level
  is 0, where the sign can also be +-0.0), and only the single q-norm
  float ships as raw bytes — so the dithered round trip is bit-exact
  under *every* value format.
* **Indexed DensePayloads** (Bernoulli sparsification) are encoded as
  their bit-level-nonzero entries plus a delta-Rice index stream — the
  index stream the estimate always charged but the in-memory payload
  never carried.

Round-trip contract: ``decode(encode(p))`` equals ``canonical(p)``
(the index-sorted twin; ``canonical`` is the identity for families
without an index stream) array-for-array, bit-exactly under
``value_format="raw"``; ``decompress(decode(encode(p)))`` equals
``decompress(p)`` for any sort order. Decoded payloads carry host
numpy arrays (bit-widths independent of the jax x64 flag); feed them
to jnp as needed.

Stacked payloads (leading silo axis, as ``jax.vmap(comp.compress)``
produces) encode per-silo via ``encode_silos`` — one byte buffer per
silo, the unit the traffic model (``repro.wire.traffic``) prices.
"""

from __future__ import annotations

import dataclasses
import struct
from typing import Iterator

import numpy as np

from ..core.compressors import (
    BlockSparsePayload,
    DensePayload,
    DitheredPayload,
    LowRankPayload,
    SparsePayload,
)
from .bitio import (
    BitReader,
    BitWriter,
    best_rice_param,
    read_rice_stream,
    unzigzag,
    write_rice_stream,
    zigzag,
)

_MAGIC = 0xFE
_VERSION = 1

_FAM_SPARSE = 1
_FAM_BLOCKSPARSE = 2
_FAM_LOWRANK = 3
_FAM_DENSE = 4
_FAM_DITHERED = 5

#: value-stream formats: raw native bytes (bit-exact), float16 cast,
#: int8 symmetric linear quantization (one f32 scale per stream)
VALUE_FORMATS = ("raw", "fp16", "int8")
_FMT_CODE = {"raw": 0, "fp16": 1, "int8": 2}
_FMT_NAME = {v: k for k, v in _FMT_CODE.items()}

_DTYPE_CODE = {np.dtype(np.float64): 0, np.dtype(np.float32): 1,
               np.dtype(np.float16): 2}
_DTYPE_FROM_CODE = {v: k for k, v in _DTYPE_CODE.items()}


class WireFormatError(ValueError):
    """Malformed or unsupported wire buffer / payload."""


# ---------------------------------------------------------------------------
# varints + value streams
# ---------------------------------------------------------------------------


def _write_varint(out: bytearray, v: int) -> None:
    if v < 0:
        raise WireFormatError(f"varint must be non-negative, got {v}")
    while True:
        b = v & 0x7F
        v >>= 7
        out.append(b | (0x80 if v else 0))
        if not v:
            return


def _read_varint(data: bytes, off: int) -> tuple[int, int]:
    out, shift = 0, 0
    while True:
        if off >= len(data):
            raise WireFormatError("truncated varint")
        b = data[off]
        off += 1
        out |= (b & 0x7F) << shift
        if not (b & 0x80):
            return out, off
        shift += 7


def _np(arr) -> np.ndarray:
    """Pull a (possibly jax) array to host; contiguity not required."""
    return np.asarray(arr)


def _dtype_code(arr: np.ndarray) -> int:
    dt = np.dtype(arr.dtype)
    if dt not in _DTYPE_CODE:
        raise WireFormatError(f"unsupported value dtype {dt}")
    return _DTYPE_CODE[dt]


def _write_values(out: bytearray, arr: np.ndarray, fmt: str) -> None:
    """One float value stream in the chosen format (count/dtype live in
    the family header, not here)."""
    flat = np.ascontiguousarray(arr).reshape(-1)
    if fmt == "raw":
        out += flat.tobytes()
    elif fmt == "fp16":
        out += flat.astype(np.float16).tobytes()
    elif fmt == "int8":
        max_abs = float(np.max(np.abs(flat))) if flat.size else 0.0
        scale = np.float32(max_abs / 127.0)
        out += struct.pack("<f", float(scale))
        if float(scale) > 0.0:
            q = np.clip(np.rint(flat / np.float64(scale)), -127, 127)
        else:
            q = np.zeros(flat.shape)
        out += q.astype(np.int8).tobytes()
    else:
        raise WireFormatError(f"unknown value format {fmt!r}")


def _read_values(data: bytes, off: int, count: int, dtype: np.dtype,
                 fmt: str) -> tuple[np.ndarray, int]:
    if fmt == "raw":
        nb = count * dtype.itemsize
        arr = np.frombuffer(data, dtype, count, off).copy()
        return arr, off + nb
    if fmt == "fp16":
        arr = np.frombuffer(data, np.float16, count, off).astype(dtype)
        return arr, off + 2 * count
    if fmt == "int8":
        (scale,) = struct.unpack_from("<f", data, off)
        q = np.frombuffer(data, np.int8, count, off + 4)
        arr = (q.astype(np.float64) * np.float64(scale)).astype(dtype)
        return arr, off + 4 + count
    raise WireFormatError(f"unknown value format {fmt!r}")


# ---------------------------------------------------------------------------
# index streams (delta + Golomb-Rice)
# ---------------------------------------------------------------------------
#
# Indices arrive as int32 with -1 reserved for padding; shifting by +1
# makes every symbol non-negative (padding = 0). Sorted mode emits
# non-negative first differences; unsorted mode zigzags the signed
# deltas. The mode byte packs the sorted flag (bit 7) with the Rice
# parameter (bits 0..4).


def _encode_index_rows(out: bytearray, idx_rows: np.ndarray) -> None:
    """Rice-code each row's sorted, shifted indices with per-row delta
    reset (rows = tiles for BlockSparse, one row for Sparse)."""
    shifted = idx_rows.astype(np.int64) + 1
    deltas = np.diff(shifted, axis=-1, prepend=0)
    flat = deltas.reshape(-1)
    if np.any(flat < 0):
        raise WireFormatError("index stream not sorted; encode sorts first")
    r = best_rice_param(flat)
    out.append(0x80 | r)
    w = BitWriter()
    write_rice_stream(w, flat.astype(np.uint64), r)
    out += w.getvalue()


def _encode_index_rows_unsorted(out: bytearray, idx_rows: np.ndarray) -> None:
    shifted = idx_rows.astype(np.int64) + 1
    deltas = np.diff(shifted, axis=-1, prepend=0)
    sym = zigzag(deltas.reshape(-1))
    r = best_rice_param(sym)
    out.append(r)
    w = BitWriter()
    write_rice_stream(w, sym, r)
    out += w.getvalue()


def _decode_index_rows(data: bytes, off: int, rows: int,
                       k: int) -> tuple[np.ndarray, int]:
    if rows * k == 0:
        return np.zeros((rows, k), np.int32), off
    mode = data[off]
    off += 1
    is_sorted, r = bool(mode & 0x80), mode & 0x1F
    rd = BitReader(data, start_bit=8 * off)
    sym = read_rice_stream(rd, rows * k, r)
    deltas = (sym.astype(np.int64) if is_sorted
              else unzigzag(sym)).reshape(rows, k)
    shifted = np.cumsum(deltas, axis=-1)
    idx = (shifted - 1).astype(np.int32)
    return idx, (rd.bit_position + 7) // 8


def _sort_pairs(values: np.ndarray, indices: np.ndarray):
    """Stable per-row sort of (value, index) pairs by index — the
    canonicalization the sorted index stream implies."""
    order = np.argsort(indices, axis=-1, kind="stable")
    return (np.take_along_axis(values, order, axis=-1),
            np.take_along_axis(indices, order, axis=-1))


# ---------------------------------------------------------------------------
# canonical form
# ---------------------------------------------------------------------------


def canonical(payload):
    """The codec's canonical twin of ``payload``: sparse families get
    their (value, index) pairs stably sorted by index per row (the order
    the sorted wire stream decodes to — dense reconstruction unchanged);
    families without an index stream are returned as-is. Arrays come
    back as host numpy."""
    if isinstance(payload, SparsePayload):
        v, i = _sort_pairs(_np(payload.values), _np(payload.indices))
        return dataclasses.replace(payload, values=v, indices=i)
    if isinstance(payload, BlockSparsePayload):
        v, i = _sort_pairs(_np(payload.values), _np(payload.indices))
        return dataclasses.replace(payload, values=v, indices=i)
    leaves, treedef = _tree_flatten(payload)
    return treedef.unflatten([_np(l) for l in leaves])


def _tree_flatten(payload):
    import jax

    return jax.tree_util.tree_flatten(payload)


# ---------------------------------------------------------------------------
# encode
# ---------------------------------------------------------------------------


def _header(fam: int, fmt: str) -> bytearray:
    return bytearray((_MAGIC, _VERSION, fam, _FMT_CODE[fmt]))


def _check_rank(arr: np.ndarray, rank: int, what: str) -> None:
    if arr.ndim != rank:
        raise WireFormatError(
            f"{what} has rank {arr.ndim}, expected {rank} — a stacked "
            f"(vmapped-over-silos) payload must go through encode_silos")


def _encode_sparse(p: SparsePayload, fmt: str, sort: bool) -> bytes:
    values, indices = _np(p.values), _np(p.indices)
    _check_rank(values, 1, "SparsePayload.values")
    out = _header(_FAM_SPARSE, fmt)
    out.append(_dtype_code(values))
    _write_varint(out, values.shape[0])
    _write_varint(out, int(p.universe))
    if values.shape[0]:
        if sort:
            values, indices = _sort_pairs(values, indices)
            _encode_index_rows(out, indices[None, :])
        else:
            _encode_index_rows_unsorted(out, indices[None, :])
    _write_values(out, values, fmt)
    return bytes(out)


def _decode_sparse(data: bytes, off: int, fmt: str) -> SparsePayload:
    dtype = _DTYPE_FROM_CODE[data[off]]
    off += 1
    k, off = _read_varint(data, off)
    universe, off = _read_varint(data, off)
    idx, off = _decode_index_rows(data, off, 1, k)
    values, off = _read_values(data, off, k, dtype, fmt)
    return SparsePayload(values=values, indices=idx.reshape(-1),
                         universe=universe)


def _encode_blocksparse(p: BlockSparsePayload, fmt: str, sort: bool) -> bytes:
    values, indices = _np(p.values), _np(p.indices)
    _check_rank(values, 2, "BlockSparsePayload.values")
    nblk, k = values.shape
    out = _header(_FAM_BLOCKSPARSE, fmt)
    out.append(_dtype_code(values))
    _write_varint(out, nblk)
    _write_varint(out, k)
    _write_varint(out, int(p.universe))
    if nblk * k:
        if sort:
            values, indices = _sort_pairs(values, indices)
            _encode_index_rows(out, indices)
        else:
            _encode_index_rows_unsorted(out, indices)
    _write_values(out, values, fmt)
    return bytes(out)


def _decode_blocksparse(data: bytes, off: int, fmt: str) -> BlockSparsePayload:
    dtype = _DTYPE_FROM_CODE[data[off]]
    off += 1
    nblk, off = _read_varint(data, off)
    k, off = _read_varint(data, off)
    universe, off = _read_varint(data, off)
    idx, off = _decode_index_rows(data, off, nblk, k)
    values, off = _read_values(data, off, nblk * k, dtype, fmt)
    return BlockSparsePayload(values=values.reshape(nblk, k), indices=idx,
                              universe=universe)


def _encode_lowrank(p: LowRankPayload, fmt: str) -> bytes:
    left, right, mid = _np(p.left), _np(p.right), _np(p.middle)
    _check_rank(left, 2, "LowRankPayload.left")
    _check_rank(mid, 1, "LowRankPayload.middle")
    out = _header(_FAM_LOWRANK, fmt)
    for arr in (left, right, mid):
        out.append(_dtype_code(arr))
    _write_varint(out, left.shape[0])
    _write_varint(out, right.shape[0])
    _write_varint(out, left.shape[1])
    _write_varint(out, mid.shape[0])
    for arr in (left, right, mid):
        _write_values(out, arr, fmt)
    return bytes(out)


def _decode_lowrank(data: bytes, off: int, fmt: str) -> LowRankPayload:
    dts = [_DTYPE_FROM_CODE[data[off + i]] for i in range(3)]
    off += 3
    d0, off = _read_varint(data, off)
    d1, off = _read_varint(data, off)
    r, off = _read_varint(data, off)
    mid, off = _read_varint(data, off)
    left, off = _read_values(data, off, d0 * r, dts[0], fmt)
    right, off = _read_values(data, off, d1 * r, dts[1], fmt)
    middle, off = _read_values(data, off, mid, dts[2], fmt)
    return LowRankPayload(left=left.reshape(d0, r),
                          right=right.reshape(d1, r), middle=middle)


def _bitwise_nonzero(flat: np.ndarray) -> np.ndarray:
    """Entries whose *bit pattern* is non-zero (keeps -0.0, which must
    round-trip for the indexed dense wire)."""
    width = {8: np.uint64, 4: np.uint32, 2: np.uint16}[flat.dtype.itemsize]
    return np.nonzero(flat.view(width) != 0)[0]


def _encode_dense(p: DensePayload, fmt: str) -> bytes:
    values = _np(p.values)
    out = _header(_FAM_DENSE, fmt)
    out.append(_dtype_code(values))
    out.append(1 if p.indexed else 0)
    _write_varint(out, values.ndim)
    for s in values.shape:
        _write_varint(out, int(s))
    _write_varint(out, int(p.count))
    _write_varint(out, int(p.universe))
    if p.indexed:
        # the estimate's index stream, made real: ship only the occupied
        # slots (bit-level non-zero, so -0.0 survives) + their indices
        flat = np.ascontiguousarray(values).reshape(-1)
        nz = _bitwise_nonzero(flat)
        _write_varint(out, nz.shape[0])
        if nz.shape[0]:
            _encode_index_rows(out, nz[None, :].astype(np.int64))
        _write_values(out, flat[nz], fmt)
    else:
        _write_values(out, values, fmt)
    return bytes(out)


def _decode_dense(data: bytes, off: int, fmt: str) -> DensePayload:
    dtype = _DTYPE_FROM_CODE[data[off]]
    indexed = bool(data[off + 1])
    off += 2
    ndim, off = _read_varint(data, off)
    shape = []
    for _ in range(ndim):
        s, off = _read_varint(data, off)
        shape.append(s)
    count, off = _read_varint(data, off)
    universe, off = _read_varint(data, off)
    numel = int(np.prod(shape)) if shape else 1
    if indexed:
        nnz, off = _read_varint(data, off)
        idx, off = _decode_index_rows(data, off, 1, nnz)
        vals, off = _read_values(data, off, nnz, dtype, fmt)
        flat = np.zeros(numel, dtype)
        flat[idx.reshape(-1)] = vals
        values = flat.reshape(shape)
    else:
        values, off = _read_values(data, off, numel, dtype, fmt)
        values = values.reshape(shape)
    return DensePayload(values=values, count=count, indexed=indexed,
                        universe=universe)


def _encode_dithered(p: DitheredPayload, fmt: str) -> bytes:
    norm, signs, levels = _np(p.norm), _np(p.signs), _np(p.levels)
    lev = np.ascontiguousarray(levels).reshape(-1)
    sgn = np.ascontiguousarray(signs).reshape(-1)
    lev_i = np.rint(lev).astype(np.int64)
    if np.any(lev_i != lev) or np.any(lev_i < 0) or np.any(lev_i > p.s):
        raise WireFormatError(
            f"dithered levels must be integer-valued in [0, {p.s}]")
    if np.any((lev_i > 0) & (sgn == 0)):
        raise WireFormatError("positive level with zero sign is unencodable")
    out = _header(_FAM_DITHERED, fmt)
    out.append(_dtype_code(signs))
    _write_varint(out, int(p.s))
    _write_varint(out, signs.ndim)
    for s in signs.shape:
        _write_varint(out, int(s))
    out += np.ascontiguousarray(norm).reshape(-1)[:1].tobytes()  # always raw
    lbits = max(1, int(p.s).bit_length())
    w = BitWriter()
    negbit = np.signbit(sgn)
    for i in range(lev_i.shape[0]):
        li = int(lev_i[i])
        w.write(li, lbits)
        if li > 0:
            w.write(1 if negbit[i] else 0, 1)
        else:
            # level 0: sign in {+0.0, +1, -1, -0.0} -> 2 bits
            si = sgn[i]
            if si == 0:
                w.write(3 if negbit[i] else 0, 2)
            else:
                w.write(2 if negbit[i] else 1, 2)
    out += w.getvalue()
    return bytes(out)


def _decode_dithered(data: bytes, off: int, fmt: str) -> DitheredPayload:
    dtype = _DTYPE_FROM_CODE[data[off]]
    off += 1
    s, off = _read_varint(data, off)
    ndim, off = _read_varint(data, off)
    shape = []
    for _ in range(ndim):
        dim, off = _read_varint(data, off)
        shape.append(dim)
    norm = np.frombuffer(data, dtype, 1, off).copy()
    off += dtype.itemsize
    numel = int(np.prod(shape)) if shape else 1
    lbits = max(1, int(s).bit_length())
    rd = BitReader(data, start_bit=8 * off)
    levels = np.empty(numel, np.int64)
    signs = np.empty(numel, np.float64)
    for i in range(numel):
        li = rd.read(lbits)
        levels[i] = li
        if li > 0:
            signs[i] = -1.0 if rd.read(1) else 1.0
        else:
            code = rd.read(2)
            signs[i] = (0.0, 1.0, -1.0, -0.0)[code]
    return DitheredPayload(norm=norm,
                           signs=signs.astype(dtype).reshape(shape),
                           levels=levels.astype(dtype).reshape(shape),
                           s=s, count=numel)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def encode(payload, value_format: str = "raw",
           sort_indices: bool = True) -> bytes:
    """Serialize ONE payload (no leading silo axis) to wire bytes.

    ``value_format`` selects the value-stream coding ("raw" is
    bit-exact; "fp16"/"int8" are quantized with the documented bounds —
    dithered payloads are categorical and bit-exact under every
    format). ``sort_indices=False`` preserves the payload's pair order
    at the cost of a larger (zigzag) index stream; the default sorts,
    so ``decode(encode(p)) == canonical(p)``."""
    if value_format not in VALUE_FORMATS:
        raise WireFormatError(
            f"value_format must be one of {VALUE_FORMATS}, "
            f"got {value_format!r}")
    if isinstance(payload, SparsePayload):
        return _encode_sparse(payload, value_format, sort_indices)
    if isinstance(payload, BlockSparsePayload):
        return _encode_blocksparse(payload, value_format, sort_indices)
    if isinstance(payload, LowRankPayload):
        return _encode_lowrank(payload, value_format)
    if isinstance(payload, DensePayload):
        return _encode_dense(payload, value_format)
    if isinstance(payload, DitheredPayload):
        return _encode_dithered(payload, value_format)
    raise WireFormatError(f"no codec for payload type {type(payload).__name__}")


def decode(data: bytes, shape=None):
    """Deserialize wire bytes back into a payload (host numpy arrays).

    All structure lives in the buffer's header; ``shape`` is accepted
    for API symmetry with ``Compressor.decompress(payload, shape)`` and
    is only validated (dense/dithered families), never required."""
    if len(data) < 4 or data[0] != _MAGIC:
        raise WireFormatError("not a wire buffer (bad magic)")
    if data[1] != _VERSION:
        raise WireFormatError(f"unsupported wire version {data[1]}")
    fam, fmt = data[2], _FMT_NAME.get(data[3])
    if fmt is None:
        raise WireFormatError(f"unknown value-format code {data[3]}")
    off = 4
    if fam == _FAM_SPARSE:
        payload = _decode_sparse(data, off, fmt)
    elif fam == _FAM_BLOCKSPARSE:
        payload = _decode_blocksparse(data, off, fmt)
    elif fam == _FAM_LOWRANK:
        payload = _decode_lowrank(data, off, fmt)
    elif fam == _FAM_DENSE:
        payload = _decode_dense(data, off, fmt)
    elif fam == _FAM_DITHERED:
        payload = _decode_dithered(data, off, fmt)
    else:
        raise WireFormatError(f"unknown payload family code {fam}")
    if shape is not None and isinstance(payload,
                                        (DensePayload, DitheredPayload)):
        got = (payload.values.shape if isinstance(payload, DensePayload)
               else payload.signs.shape)
        if tuple(int(s) for s in shape) != tuple(got):
            raise WireFormatError(f"shape mismatch: buffer carries {got}, "
                                  f"caller expected {tuple(shape)}")
    return payload


def encode_silos(payloads, value_format: str = "raw",
                 sort_indices: bool = True) -> Iterator[bytes]:
    """Encode a STACKED payload (leading silo axis, the output of
    ``jax.vmap(comp.compress)``) one silo at a time — one byte buffer
    per silo, which is the unit the traffic model prices.

    LAZY: yields each silo's buffer as it is encoded instead of
    materializing all n at once — at cross-device cohort sizes
    (n = 10k+) the encoded buffers would otherwise dominate host
    memory. Wrap in ``list(...)`` when random access is needed. The
    stacked arrays are pulled to host once, up front (one copy of the
    wire-sized payload, which the caller already holds); only the
    per-silo buffers stream."""
    import jax

    leaves = jax.tree_util.tree_leaves(payloads)
    if not leaves:
        return
    n = int(leaves[0].shape[0])
    host = jax.tree_util.tree_map(_np, payloads)
    for i in range(n):
        yield encode(jax.tree_util.tree_map(lambda a: a[i], host),
                     value_format=value_format, sort_indices=sort_indices)


def encoded_bytes(payload, value_format: str = "raw") -> int:
    """Actual wire size in BYTES of one payload: ``len(encode(...))``.
    The measured-by-codec fourth column next to the analytic / raw /
    entropy bit estimates (see ``repro.wire.report.wire_cost``)."""
    return len(encode(payload, value_format=value_format))
