"""``WireReport`` — the one wire-cost surface.

Three PRs grew four overlapping ways to ask "how big is this
compressor's uplink": ``comp.spec(shape).bits`` / ``comp.bits(shape)``
(the paper's analytic claim), ``payload_bits(comp, shape)`` (measured
payload structure, raw index streams), ``payload_bits(...,
index_coding="entropy")`` (the entropy-coded index estimate), and — new
with the codec — the *actual* encoded buffer. ``wire_cost(comp, shape)``
collapses them into one call returning one dataclass:

    rep = wire_cost(comp, (d, d))
    rep.analytic_bits   # comp.spec(shape).bits — the paper's x-axis
    rep.raw_bits        # measured payload structure, raw 32-bit indices
    rep.entropy_bits    # same, index streams entropy-coded (estimate)
    rep.encoded_bytes   # len(codec.encode(payload)) on a sample input

The first three are shape-static (eval_shape — zero FLOPs); the last is
the codec run on a deterministic sample (normal(0, 1) under
``PRNGKey(0)``, or a caller-supplied matrix), because a real encoder's
output length is data-dependent — that is the whole point of having
one. The legacy callables remain as thin deprecated aliases so existing
code keeps working; new code should go through ``wire_cost``.
"""

from __future__ import annotations

import dataclasses
from typing import Union

import numpy as np

from .codec import encode_silos, encoded_bytes
from .traffic import LinkModel, round_seconds


@dataclasses.dataclass(frozen=True)
class WireReport:
    """Every wire-cost number for one (compressor, shape) pair.

    analytic_bits: the paper's analytic claim (``comp.spec(shape).bits``)
    raw_bits:      measured payload structure, raw 32-bit index streams
    entropy_bits:  measured payload structure, entropy-coded index
                   estimate (``<= raw_bits`` by construction)
    encoded_bytes: actual codec output length on the sample input
    value_format:  the codec value-stream format behind encoded_bytes
    """

    analytic_bits: int
    raw_bits: int
    entropy_bits: int
    encoded_bytes: int  # 0 when the report was built with encoded=False
    value_format: str = "raw"

    @property
    def encoded_bits(self) -> int:
        return 8 * self.encoded_bytes

    def seconds(self, link: Union[str, LinkModel], n: int = 1,
                seed: int = 0) -> float:
        """Simulated seconds to uplink the ENCODED buffer for one round
        of an n-silo cohort (``repro.wire.traffic.round_seconds``)."""
        return round_seconds(float(self.encoded_bits), link, n=n, seed=seed)


def wire_cost(comp, shape, *, dtype=None, value_format: str = "raw",
              sample=None, key=None, encoded: bool = True) -> WireReport:
    """The single wire-cost entry point: one ``WireReport`` per
    (compressor, shape).

    ``dtype`` defaults to the ambient float (f64 under x64 — the
    paper's accounting). ``sample`` supplies the matrix the codec
    encodes (defaults to a deterministic standard normal); ``key`` the
    PRNG key randomized compressors consume. ``encoded=False`` skips
    the compress + codec run entirely (``encoded_bytes`` is 0): the
    remaining three fields are shape-static (eval_shape, zero FLOPs),
    which is what per-round accounting like ``bits_per_round`` wants.
    Supersedes the deprecated quartet ``comp.bits(shape)`` /
    ``comp.spec(shape).bits`` / ``payload_bits(comp, shape)`` /
    ``payload.bits(index_coding=...)`` — all of which remain as aliases
    of the first three fields."""
    import jax
    import jax.numpy as jnp

    from ..core.compressors import payload_bits

    shape = tuple(int(s) for s in shape)
    if dtype is None:
        dtype = jnp.result_type(float)
    if encoded:
        if sample is None:
            sample = jax.random.normal(jax.random.PRNGKey(0), shape,
                                       dtype=jnp.dtype(dtype))
        if key is None:
            key = jax.random.PRNGKey(1)
        payload = comp.compress(jnp.asarray(sample, dtype=jnp.dtype(dtype)),
                                key)
        nbytes = encoded_bytes(payload, value_format=value_format)
    else:
        nbytes = 0
    return WireReport(
        analytic_bits=int(comp.spec(shape).bits),
        raw_bits=int(payload_bits(comp, shape, dtype=dtype)),
        entropy_bits=int(payload_bits(comp, shape, dtype=dtype,
                                      index_coding="entropy")),
        encoded_bytes=nbytes,
        value_format=value_format,
    )


def silo_encoded_bytes(payloads, value_format: str = "raw") -> np.ndarray:
    """Per-silo encoded sizes (bytes) of a STACKED payload — the array
    the traffic model prices for a heterogeneous cohort."""
    return np.array([len(b) for b in
                     encode_silos(payloads, value_format=value_format)],
                    dtype=np.int64)
