"""Traffic model: payload bytes x link distributions -> seconds/round.

The codec (``repro.wire.codec``) turns payloads into byte buffers; this
module turns byte buffers into *time*, so sweeps can rank methods by
simulated wall-clock per round instead of bits alone. The model is the
standard synchronous-FL round shape: every participating silo uploads
its payload over its own link, the server waits for the slowest
(straggler-dominated — the ``max`` reduction), and per-silo links are
heterogeneous (lognormal bandwidth spread around the preset mean,
uniform latency jitter), which is what makes the cohort size ``n``
matter: a bigger cohort samples deeper into the slow tail.

Everything is deterministic given ``seed`` (numpy Generator), so the
``seconds_per_round`` column in sweep records is reproducible.

Presets (README "wire format" section documents the table):

  ``datacenter``       10 Gbit/s, 0.5 ms — intra-DC silos (FedNL's
                       cross-silo setting at its friendliest)
  ``wan``              100 Mbit/s, 25 ms — cross-region silos; the
                       default for sweep records
  ``fl-cross-device``  20 Mbit/s, 50 ms, heavy lognormal spread —
                       phone-class uplinks (the "Unlocking FedNL"
                       practical tier)

Use ``round_seconds(bits, link, n)`` for one round of an n-silo cohort,
or ``LinkModel(...)`` directly for custom links.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Union

import numpy as np


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """One uplink class.

    bandwidth_bps:   mean uplink bandwidth, bits/second (the lognormal
                     per-silo draw is mean-corrected, so the *average*
                     silo sees exactly this)
    latency_s:       fixed per-message latency, seconds
    bandwidth_sigma: lognormal sigma of the per-silo bandwidth spread
                     (0 = every silo identical)
    latency_jitter_s: half-width of uniform latency jitter
    """

    name: str
    bandwidth_bps: float
    latency_s: float
    bandwidth_sigma: float = 0.0
    latency_jitter_s: float = 0.0

    def silo_bandwidths(self, n: int, seed: int = 0) -> np.ndarray:
        """(n,) per-silo bandwidth draws, mean-corrected lognormal."""
        if self.bandwidth_sigma <= 0.0:
            return np.full(n, float(self.bandwidth_bps))
        rng = np.random.default_rng(seed)
        # E[lognormal(mu, s)] = exp(mu + s^2/2); pick mu so the mean is 1
        s = float(self.bandwidth_sigma)
        draw = rng.lognormal(mean=-0.5 * s * s, sigma=s, size=n)
        return self.bandwidth_bps * draw

    def silo_seconds(self, bits_per_silo: float, n: int,
                     seed: int = 0) -> np.ndarray:
        """(n,) per-silo upload times for one round: latency (+ jitter)
        plus transfer time at each silo's drawn bandwidth."""
        bw = self.silo_bandwidths(n, seed=seed)
        lat = np.full(n, float(self.latency_s))
        if self.latency_jitter_s > 0.0:
            rng = np.random.default_rng(seed + 1)
            lat = lat + rng.uniform(0.0, self.latency_jitter_s, size=n)
        return lat + float(bits_per_silo) / bw


#: named link presets — ``link_model("wan")`` etc.; the README documents
#: this table next to the measured wire sizes
PRESETS = {
    "datacenter": LinkModel("datacenter", bandwidth_bps=10e9,
                            latency_s=0.0005, bandwidth_sigma=0.1,
                            latency_jitter_s=0.0002),
    "wan": LinkModel("wan", bandwidth_bps=100e6, latency_s=0.025,
                     bandwidth_sigma=0.5, latency_jitter_s=0.005),
    "fl-cross-device": LinkModel("fl-cross-device", bandwidth_bps=20e6,
                                 latency_s=0.05, bandwidth_sigma=0.75,
                                 latency_jitter_s=0.02),
}


def link_model(link: Union[str, LinkModel, None]) -> Optional[LinkModel]:
    """Resolve a preset name (or pass a LinkModel through; None -> None)."""
    if link is None or isinstance(link, LinkModel):
        return link
    try:
        return PRESETS[link]
    except KeyError:
        raise ValueError(f"unknown link preset {link!r}; "
                         f"known: {sorted(PRESETS)}") from None


def round_seconds(bits_per_silo: float, link: Union[str, LinkModel],
                  n: int = 1, seed: int = 0, reduce: str = "max") -> float:
    """Simulated seconds for ONE synchronous round of an ``n``-silo
    cohort each uplinking ``bits_per_silo`` bits.

    ``reduce="max"`` is the synchronous server (waits for the straggler
    — the FedNL deployment model); ``"mean"`` approximates a fully
    async/streaming server where per-silo uploads overlap."""
    model = link_model(link)
    t = model.silo_seconds(bits_per_silo, max(1, int(n)), seed=seed)
    if reduce == "max":
        return float(np.max(t))
    if reduce == "mean":
        return float(np.mean(t))
    raise ValueError(f"reduce must be 'max' or 'mean', got {reduce!r}")


def seconds_curve(bits_per_round: float, link: Union[str, LinkModel],
                  n: int, num_rounds: int, init_bits: float = 0.0,
                  seed: int = 0) -> np.ndarray:
    """(num_rounds+1,) cumulative simulated seconds — the time-domain
    twin of ``engine.records.bits_curve``. The link draw is fixed per
    cohort (silos keep their links across rounds), so the curve is the
    per-round time times the round index, plus a one-time cost for the
    init ship when ``init_bits`` is set."""
    per = round_seconds(bits_per_round, link, n, seed=seed)
    t0 = round_seconds(init_bits, link, n, seed=seed) if init_bits else 0.0
    return t0 + per * np.arange(num_rounds + 1)


def transfer_seconds(nbytes: int, link: Union[str, LinkModel],
                     n: int = 1, seed: int = 0) -> float:
    """Convenience: ``round_seconds`` for a payload given in bytes."""
    return round_seconds(8.0 * float(nbytes), link, n=n, seed=seed)
