"""Seed-era dense compressor implementations, pinned verbatim as the
bit-identity oracle for the payload wire-format API. Imported by both
test_payloads.py (no optional deps) and test_compressors.py (hypothesis
fuzzing) so the two suites assert against ONE reference."""

import jax
import jax.numpy as jnp


def topk_dense_ref(m, k, symmetric=False):
    def dense(t, kk):
        flat = t.reshape(-1)
        kk = min(kk, flat.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(flat), kk)
        return jnp.zeros_like(flat).at[idx].set(flat[idx]).reshape(t.shape)

    if symmetric:
        c = dense(jnp.tril(m), k)
        return c + c.T - jnp.diag(jnp.diag(c))
    return dense(m, k)


def randk_dense_ref(m, k, key):
    flat = m.reshape(-1)
    n = flat.shape[0]
    k = min(k, n)
    idx = jax.random.choice(key, n, (k,), replace=False)
    mask = jnp.zeros((n,), m.dtype).at[idx].set(1.0)
    return (flat * mask * (n / k)).reshape(m.shape)


def blocktopk_dense_ref(m, k, b):
    d0, d1 = m.shape
    p0, p1 = (-d0) % b, (-d1) % b
    mp = jnp.pad(m, ((0, p0), (0, p1)))
    n0, n1 = mp.shape[0] // b, mp.shape[1] // b
    tiles = mp.reshape(n0, b, n1, b).transpose(0, 2, 1, 3) \
        .reshape(n0 * n1, b * b)
    kk = min(k, b * b)
    _, idx = jax.lax.top_k(jnp.abs(tiles), kk)
    vals = jnp.take_along_axis(tiles, idx, axis=1)
    out = jnp.zeros_like(tiles)
    out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)
    return out.reshape(n0, n1, b, b).transpose(0, 2, 1, 3) \
        .reshape(mp.shape)[:d0, :d1]


def rankr_dense_ref(m, r, symmetric=True):
    if symmetric:
        sym = 0.5 * (m + m.T)
        lam, q = jnp.linalg.eigh(sym)
        r = min(r, lam.shape[0])
        _, idx = jax.lax.top_k(jnp.abs(lam), r)
        return (q[:, idx] * lam[idx]) @ q[:, idx].T
    u, s, vt = jnp.linalg.svd(m, full_matrices=False)
    r = min(r, s.shape[0])
    return (u[:, :r] * s[:r]) @ vt[:r, :]
