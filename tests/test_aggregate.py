"""Payload-space server aggregation tests.

Pins the tentpole equivalence — for every registered compressor family,
``comp.aggregate(stacked payloads) == mean_i decompress(payload_i)`` to
f64 tolerance — under the plain path, under vmap over seeds, and under
shard_map over silos, including the -1 padding and k-ties edge cases of
the wire format; plus the ``scale_payload`` masked mean (partial
participation), end-to-end FedNL/FedNL-PP run equivalence fast-path vs
fallback, the fednl_precond silo-axis observation path, and the
entropy-coded index-stream accounting."""

import math
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import FedNL, FedNLPP, TopK
from repro.core.compressors import (
    BlockSparsePayload,
    BlockTopKThreshold,
    Compressor,
    SparsePayload,
    available_compressors,
    make_compressor,
    payload_bits,
)
from repro.core.objectives import batch_grad, batch_hess
from repro.data.synthetic import make_synthetic

# every registered family with a usable level (mirrors test_payloads)
_FAMILY_LEVELS = {
    "rankr": 2, "rank": 2, "topk": 17, "topksym": 17, "powersgd": 2,
    "randk": 17, "blocktopk": 5, "blocktopkthreshold": 5,
    "natural": 0.4, "identity": None, "none": None, "zero": None,
    "dithering": 4, "randomdithering": 4,
}

N_SILOS = 5


def _family_shape(family):
    return (12,) if family in ("dithering", "randomdithering") else (12, 12)


def _stacked_payloads(comp, shape, n=N_SILOS, seed=0):
    stack = jax.random.normal(jax.random.PRNGKey(seed), (n,) + shape)
    keys = jax.random.split(jax.random.PRNGKey(seed + 1), n)
    return stack, jax.vmap(comp.compress)(stack, keys)


def test_every_registered_family_covered():
    missing = [f for f in available_compressors() if f not in _FAMILY_LEVELS]
    assert not missing, f"no aggregate coverage for families {missing}"


@pytest.mark.parametrize("family", sorted(_FAMILY_LEVELS))
def test_aggregate_matches_decompress_mean(family):
    """Acceptance: aggregate == mean of per-silo decompression, per
    registered family, at f64 tolerance (reduction order differs)."""
    with enable_x64():
        comp = make_compressor(family, _FAMILY_LEVELS[family])
        shape = _family_shape(family)
        _, payloads = _stacked_payloads(comp, shape)
        fast = comp.aggregate(payloads, shape)
        slow = Compressor.aggregate(comp, payloads, shape)  # fallback
        scale = float(jnp.max(jnp.abs(slow))) + 1e-30
        np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                                   rtol=0, atol=1e-13 * max(1.0, scale))


@pytest.mark.parametrize("family", [
    "topk", "topksym", "randk", "blocktopk", "blocktopkthreshold",
    "rankr", "powersgd", "identity", "natural", "dithering", "zero"])
def test_aggregate_fast_path_is_registered(family):
    """Guard: the structure-aware families must actually override the
    generic decompress-then-mean fallback — a silent fallback would
    reintroduce the (n, d, d) server stack."""
    comp = make_compressor(family, _FAMILY_LEVELS[family])
    assert type(comp).aggregate is not Compressor.aggregate, family


def test_aggregate_under_vmap_over_seeds():
    """The engine vmaps whole steps over the seed axis; aggregate must
    batch transparently and match the per-seed serial results."""
    with enable_x64():
        comp = make_compressor("randk", 13)
        shape = (12, 12)
        stack = jax.random.normal(jax.random.PRNGKey(0),
                                  (N_SILOS,) + shape)

        def one(seed_key):
            keys = jax.random.split(seed_key, N_SILOS)
            payloads = jax.vmap(comp.compress)(stack, keys)
            return comp.aggregate(payloads, shape)

        seed_keys = jax.random.split(jax.random.PRNGKey(7), 3)
        batched = jax.jit(jax.vmap(one))(seed_keys)
        for i in range(3):
            np.testing.assert_allclose(np.asarray(batched[i]),
                                       np.asarray(one(seed_keys[i])),
                                       rtol=0, atol=1e-14)


def test_aggregate_under_shard_map_over_silos():
    """Real 4-way shard_map over the silo axis: per-shard payload-space
    aggregation + one pmean of the dense (d, d) accumulator equals the
    serial aggregate over the full stack. Subprocess so the forced host
    device count doesn't leak into this session."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_enable_x64", True)
        from functools import partial
        from jax.sharding import PartitionSpec as P
        try:
            from jax import shard_map as shard_map
        except ImportError:
            from jax.experimental.shard_map import shard_map
        from repro.core.compressors import SparsePayload, TopK

        comp = TopK(k=50)
        shape = (12, 12)
        n = 8
        stack = jax.random.normal(jax.random.PRNGKey(0), (n,) + shape)
        keys = jax.random.split(jax.random.PRNGKey(1), n)
        payloads = jax.vmap(comp.compress)(stack, keys)
        serial = comp.aggregate(payloads, shape)

        mesh = jax.make_mesh((4,), ("data",))

        @partial(shard_map, mesh=mesh, in_specs=(P("data"), P("data")),
                 out_specs=P())
        def sharded_agg(values, indices):
            local = SparsePayload(values=values, indices=indices,
                                  universe=comp._slots(shape))
            return jax.lax.pmean(comp.aggregate(local, shape), "data")

        out = sharded_agg(payloads.values, payloads.indices)
        np.testing.assert_allclose(np.asarray(out), np.asarray(serial),
                                   rtol=0, atol=1e-14)
        print("SHARDED_AGG_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_AGG_OK" in out.stdout, out.stdout + out.stderr


# -- wire-format edge cases ---------------------------------------------------


def test_aggregate_sparse_negative_padding_dropped():
    """-1 payload padding must vanish from the aggregate even when its
    value slot is nonzero (same regression class as decompress: jax
    normalizes negative indices ahead of mode='drop')."""
    with enable_x64():
        pay = SparsePayload(
            values=jnp.asarray([[1.0, 2.0, 3.0], [4.0, 5.0, 0.0]]),
            indices=jnp.asarray([[0, 5, -1], [5, -1, -1]], jnp.int32),
            universe=6)
        comp = TopK(k=3)
        out = comp.aggregate(pay, (2, 3))
        np.testing.assert_allclose(
            np.asarray(out), [[0.5, 0.0, 0.0], [0.0, 0.0, 3.0]],
            rtol=0, atol=0)
        slow = Compressor.aggregate(comp, pay, (2, 3))
        np.testing.assert_allclose(np.asarray(out), np.asarray(slow),
                                   rtol=0, atol=0)


def test_aggregate_blocksparse_ties_and_padding():
    """BlockTopKThreshold payloads under a tie cluster spanning the k-th
    position carry -1 padding and exactly-k survivors (PR-2 semantics);
    the per-tile scatter-add aggregate must agree with the fallback."""
    with enable_x64():
        comp = BlockTopKThreshold(k_per_block=3, block=4)
        base = jnp.full((4, 4), 1.0).at[0, 0].set(1.0001)
        stack = jnp.stack([base, 2.0 * base, -base])
        payloads = jax.vmap(lambda m: comp.compress(m))(stack)
        assert bool(jnp.any(payloads.indices >= 0))
        fast = comp.aggregate(payloads, (4, 4))
        slow = Compressor.aggregate(comp, payloads, (4, 4))
        np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                                   rtol=0, atol=1e-15)


def test_aggregate_blocksparse_nonmultiple_shape_cropped():
    """Shapes that don't divide the block: padded tiles accumulate zeros
    and the aggregate crops back to the true shape."""
    with enable_x64():
        comp = make_compressor("blocktopk", 5)  # block=128 > shape
        shape = (10, 14)
        _, payloads = _stacked_payloads(comp, shape, seed=3)
        fast = comp.aggregate(payloads, shape)
        slow = Compressor.aggregate(comp, payloads, shape)
        assert fast.shape == shape
        np.testing.assert_allclose(np.asarray(fast), np.asarray(slow),
                                   rtol=0, atol=1e-15)


@pytest.mark.parametrize("family", ["topk", "rankr", "dithering", "natural"])
def test_scale_payload_masked_mean(family):
    """aggregate(p, shape, weights=w) == mean_i w_i * decompress_i — the
    partial-participation masking used by FedNL-PP/PPBC, across wire
    formats (values / low-rank middle / dithering signs); the weighting
    is ``scale_payload`` applied inside the aggregate entry point."""
    with enable_x64():
        comp = make_compressor(family, _FAMILY_LEVELS[family])
        shape = _family_shape(family)
        _, payloads = _stacked_payloads(comp, shape, seed=4)
        w = jnp.asarray([1.0, 0.0, 1.0, 1.0, 0.0])
        out = comp.aggregate(payloads, shape, weights=w)
        dec = jax.vmap(lambda p: comp.decompress(p, shape))(payloads)
        ref = jnp.mean(w.reshape((-1,) + (1,) * len(shape)) * dec, axis=0)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=1e-13)


# -- end-to-end: serial .run numerics unchanged -------------------------------


class _FallbackTopK(TopK):
    """TopK forced onto the generic decompress-then-mean server."""

    def aggregate(self, payloads, shape, weights=None):
        return Compressor.aggregate(self, payloads, shape, weights=weights)


@pytest.fixture(scope="module")
def problem():
    with enable_x64():
        data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                              n=6, m=40, d=10, lam=1e-3)
        data = data._replace(a=data.a.astype(jnp.float64),
                             b=data.b.astype(jnp.float64))
        yield dict(grad=lambda x: batch_grad(x, data),
                   hess=lambda x: batch_hess(x, data), n=6, d=10)


def test_fednl_run_fast_path_matches_fallback(problem):
    """Swapping the structure-aware aggregate for decompress-then-mean
    must not move serial .run trajectories beyond f64 noise."""
    with enable_x64():
        x0 = jnp.full((10,), 0.4, jnp.float64)
        runs = {}
        for tag, comp in [("fast", TopK(k=30)), ("slow", _FallbackTopK(k=30))]:
            alg = FedNL(problem["grad"], problem["hess"], comp, option=2)
            _, runs[tag] = alg.run(x0, problem["n"], 8)
        np.testing.assert_allclose(np.asarray(runs["fast"]),
                                   np.asarray(runs["slow"]),
                                   rtol=0, atol=1e-12)


def test_fednl_pp_masked_fast_path_matches_fallback(problem):
    """FedNL-PP's masked server aggregate (zero-weighted inactive silos
    in payload space) equals the dense masked mean, end to end."""
    with enable_x64():
        x0 = jnp.full((10,), 0.4, jnp.float64)
        runs = {}
        for tag, comp in [("fast", TopK(k=30)), ("slow", _FallbackTopK(k=30))]:
            alg = FedNLPP(problem["grad"], problem["hess"], comp, tau=3)
            _, runs[tag] = alg.run(x0, problem["n"], 8)
        np.testing.assert_allclose(np.asarray(runs["fast"]),
                                   np.asarray(runs["slow"]),
                                   rtol=0, atol=1e-12)


# -- large-d: the tiled accumulator ------------------------------------------


@pytest.mark.slow
def test_aggregate_topk_randk_exact_at_d4096_via_tiled_kernel():
    """Acceptance: TopK/RandK aggregate is exact (f64, vs decompress-
    then-mean) at d=4096 — and the Pallas TILED scatter kernel (the
    budget dispatch auto-tiles: 4096^2 f64 >> 8 MiB) reproduces the
    same sum bit-for-bit against the XLA oracle."""
    from repro.core.compressors import RandK
    from repro.kernels.scatter_accum import scatter_accumulate

    with enable_x64():
        d, n = 4096, 2
        stack = jax.random.normal(jax.random.PRNGKey(0), (n, d, d))
        keys = jax.random.split(jax.random.PRNGKey(1), n)
        for comp in (TopK(k=64), RandK(k=64)):
            pay = jax.vmap(comp.compress)(stack, keys)
            fast = comp.aggregate(pay, (d, d))
            fallback = Compressor.aggregate(comp, pay, (d, d))
            scale = float(jnp.max(jnp.abs(fallback))) + 1e-30
            err = float(jnp.max(jnp.abs(fast - fallback)))
            assert err <= 1e-12 * max(1.0, scale), (type(comp).__name__, err)
            # force the Pallas path: the budget dispatch must pick the
            # tiled kernel and agree with the aggregate exactly
            tiled = scatter_accumulate(pay.values, pay.indices, (d, d),
                                       use_pallas=True, interpret=True) / n
            err_t = float(jnp.max(jnp.abs(tiled - fast)))
            assert err_t <= 1e-12 * max(1.0, scale), (type(comp).__name__,
                                                      err_t)


# -- fednl_precond silo-axis observations -------------------------------------


def test_fednl_precond_silo_axis_aggregates_payloads():
    """Observations with a leading silo axis: H learns from the payload-
    space mean of per-silo compressed diffs (here k = block^2, so the
    compression is exact and H must equal the mean observation)."""
    from repro.second_order.fednl_precond import FedNLPrecondOptimizer

    opt = FedNLPrecondOptimizer(lr=0.1, alpha=1.0, k_per_block=64, block=8)
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)
    grads = {"w": jnp.ones((8, 8))}
    obs = {"w": jnp.stack([jnp.full((8, 8), v) for v in (1.0, 2.0, 6.0)])}
    _, state = opt.update(grads, state, params, observations=obs)
    np.testing.assert_allclose(np.asarray(state.h["w"]), 3.0, atol=1e-6)


def test_fednl_precond_adapter_threads_observations():
    """Regression: the Optimizer-protocol adapter used to wrap update in
    a 3-arg lambda, silently dropping ``observations`` — the PR 3
    cross-silo branch was dead code through the protocol. The adapter
    must drive it, and the plain 3-arg call must keep working."""
    from repro.second_order import fednl_precond

    opt = fednl_precond(0.1, alpha=1.0, k_per_block=64, block=8)
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)
    grads = {"w": jnp.ones((8, 8))}
    obs = {"w": jnp.stack([jnp.full((8, 8), v) for v in (1.0, 2.0, 6.0)])}
    _, state = opt.update(grads, state, params, observations=obs)
    # k = block^2 -> exact compression: H must equal the silo mean,
    # which is only reachable if observations survived the adapter
    np.testing.assert_allclose(np.asarray(state.h["w"]), 3.0, atol=1e-6)
    upd, state = opt.update(grads, state, params)  # 3-arg still fine
    assert jax.tree.leaves(upd)[0].shape == (8, 8)


def test_fednl_precond_silo_axis_matches_per_silo_reference():
    """Lossy case (k < block^2): the update equals the mean of each
    silo's individually compressed diff — the paper's placement."""
    from repro.second_order.fednl_precond import FedNLPrecondOptimizer

    opt = FedNLPrecondOptimizer(lr=0.1, alpha=0.5, k_per_block=9, block=8)
    comp = opt.compressor
    params = {"w": jnp.zeros((8, 8))}
    state = opt.init(params)
    grads = {"w": jnp.ones((8, 8))}
    sil = jax.random.normal(jax.random.PRNGKey(2), (3, 8, 8)) ** 2
    _, new_state = opt.update(grads, state, params, observations={"w": sil})
    ref = 0.5 * jnp.mean(jax.vmap(lambda t: comp(t))(sil), axis=0)
    np.testing.assert_allclose(np.asarray(new_state.h["w"]),
                               np.asarray(ref), atol=1e-6)


# -- entropy-coded index-stream accounting ------------------------------------


def test_entropy_index_bits_below_raw_for_sparse():
    with enable_x64():
        comp = TopK(k=16)
        raw = payload_bits(comp, (32, 32))
        ent = payload_bits(comp, (32, 32), index_coding="entropy")
        assert ent < raw
        # value stream unchanged: the saving is entirely index-side
        assert raw - ent <= 16 * 32


def test_entropy_index_bits_formula():
    """ceil(log2 C(universe, k)), capped at raw k*32 — checked against
    exact math.comb (lgamma evaluation may differ by <= 1 bit)."""
    pay = SparsePayload(values=jnp.zeros((16,)),
                        indices=jnp.zeros((16,), jnp.int32), universe=1024)
    got = pay.bits(index_coding="entropy") - pay.bits() + 16 * 32
    want = math.ceil(math.log2(math.comb(1024, 16)))
    assert abs(got - want) <= 1


def test_entropy_index_bits_edge_cases():
    # k == universe: the index set is fully determined -> 0 index bits,
    # leaving only the value stream (9 f32 values here)
    full = SparsePayload(values=jnp.zeros((9,), jnp.float32),
                         indices=jnp.zeros((9,), jnp.int32), universe=9)
    assert full.bits(index_coding="entropy") == 9 * 32
    # empty payload (Zero): no bits at all
    empty = SparsePayload(values=jnp.zeros((0,)),
                          indices=jnp.zeros((0,), jnp.int32), universe=100)
    assert empty.bits(index_coding="entropy") == 0
    # unknown universe: falls back to raw
    unk = SparsePayload(values=jnp.zeros((4,)),
                        indices=jnp.zeros((4,), jnp.int32))
    assert unk.bits(index_coding="entropy") == unk.bits()


def test_entropy_bits_blocksparse_scales_with_tiles():
    pay = BlockSparsePayload(values=jnp.zeros((6, 4), jnp.float32),
                             indices=jnp.zeros((6, 4), jnp.int32),
                             universe=64)
    per_tile = math.ceil(math.log2(math.comb(64, 4)))
    got = pay.bits(index_coding="entropy")
    assert abs(got - 6 * (4 * 32 + per_tile)) <= 6


def test_sweep_records_carry_entropy_column(problem):
    """Sweep rows surface bits_entropy as a third accounting column:
    <= the raw measured column always, strictly below it for index-
    carrying sparsifiers."""
    from repro.engine import ExperimentSpec, Sweep

    with enable_x64():
        spec = ExperimentSpec("fednl", "topk", 20,
                              params=dict(option=2), num_rounds=2)
        res = Sweep([spec]).run(
            dict(grad=problem["grad"], hess=problem["hess"],
                 n=problem["n"], d=problem["d"]),
            x0=jnp.zeros(problem["d"], jnp.float64))
        cell = res.cells[0]
        assert cell.bits_entropy is not None
        assert np.all(cell.bits_entropy <= cell.bits_measured)
        assert cell.bits_entropy[-1] < cell.bits_measured[-1]
        rows = res.records()
        assert all(r["bits_entropy"] <= r["bits_measured"] for r in rows)
        summ = res.summary()
        assert 0 < summ[0]["bits_per_round_entropy"] < \
            summ[0]["bits_per_round_measured"]


# -- fused diff -> top-k -> payload uplink ------------------------------------


class _UnfusedView:
    """Proxy hiding ``fused_diff_payloads`` so MethodBase falls back to
    the unfused compress(h_new - h_old) + frob_norm uplink."""

    def __init__(self, inner):
        self._inner = inner

    def __getattr__(self, name):
        if name == "fused_diff_payloads":
            raise AttributeError(name)
        return getattr(self._inner, name)


def test_fused_diff_payloads_matches_unfused_compress():
    """Compressor-level pin at f64: the fused device uplink (one-pass
    diff -> select -> payload + ||D||_F) equals compressing the
    materialized diff, silo by silo."""
    from repro.core.compressors import BlockTopK
    from repro.core.linalg import frob_norm

    with enable_x64():
        comp = BlockTopK(k_per_block=9, block=8)
        kh, ko = jax.random.split(jax.random.PRNGKey(21))
        h_new = jax.random.normal(kh, (3, 16, 16), jnp.float64)
        h_old = jax.random.normal(ko, (3, 16, 16), jnp.float64)
        payloads, l = comp.fused_diff_payloads(h_new, h_old)
        diff = h_new - h_old
        ref_pay = jax.vmap(lambda m: comp.compress(m))(diff)
        dec = lambda p: comp.decompress(p, (16, 16))
        np.testing.assert_allclose(
            np.asarray(jax.vmap(dec)(payloads)),
            np.asarray(jax.vmap(dec)(ref_pay)), rtol=0, atol=1e-12)
        np.testing.assert_allclose(np.asarray(l),
                                   np.asarray(jax.vmap(frob_norm)(diff)),
                                   rtol=1e-12)


def test_fednl_fused_uplink_run_matches_unfused(problem):
    """Method-level pin: a FedNL run through the fused uplink
    (``fused_diff_payloads``) tracks the unfused fallback trajectory to
    f64 noise — the fusion changes scheduling, not numerics."""
    from repro.core.compressors import BlockTopK

    with enable_x64():
        x0 = jnp.full((10,), 0.4, jnp.float64)
        comp = BlockTopK(k_per_block=9, block=8)
        runs = {}
        for tag, c in [("fused", comp), ("unfused", _UnfusedView(comp))]:
            alg = FedNL(problem["grad"], problem["hess"], c, option=2)
            _, runs[tag] = alg.run(x0, problem["n"], 8)
        np.testing.assert_allclose(np.asarray(runs["fused"]),
                                   np.asarray(runs["unfused"]),
                                   rtol=0, atol=1e-11)


# -- cross-device scale: streamed dispatch + sharded accumulator --------------


def test_aggregate_streams_above_vmem_budget():
    """A concrete payload stack whose (value, index) pair stream
    outgrows the kernel VMEM budget must take the streamed silo-slab
    path — and land BITWISE on the stacked kernel over the same scaled
    pairs. Traced stacks (inside jit) must keep the stacked path."""
    from repro.core.compressors import _should_stream, scale_payload
    from repro.kernels import VMEM_BUDGET_BYTES
    from repro.kernels.scatter_accum import scatter_accumulate

    with enable_x64():
        n, k, d = 700, 1024, 64
        pair = jnp.dtype(jnp.float64).itemsize + jnp.dtype(jnp.int32).itemsize
        assert n * k * pair > VMEM_BUDGET_BYTES  # the premise
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        pay = SparsePayload(
            values=jax.random.normal(ks[0], (n, k), dtype=jnp.float64),
            indices=jax.random.randint(ks[1], (n, k), 0, d * d,
                                       dtype=jnp.int32),
            universe=d * d)
        w = jax.random.uniform(ks[2], (n,), dtype=jnp.float64)
        assert _should_stream(pay.values, pay.indices)
        assert not _should_stream(
            jax.ShapeDtypeStruct((n, k), jnp.float64),
            jax.ShapeDtypeStruct((n, k), jnp.int32))

        comp = TopK(k=k)
        streamed = comp.aggregate(pay, (d, d), weights=w)  # eager: streams
        scaled = scale_payload(pay, w)
        stacked = (scatter_accumulate(scaled.values, scaled.indices,
                                      (d, d)) / n).reshape(d, d)
        np.testing.assert_array_equal(np.asarray(streamed),
                                      np.asarray(stacked))
        # inside jit the stack is a tracer: stacked kernel, same answer
        # to f64 tolerance (XLA may fuse the x*w and /n multiplies)
        jitted = jax.jit(lambda p: comp.aggregate(p, (d, d), weights=w))(pay)
        np.testing.assert_allclose(np.asarray(jitted), np.asarray(streamed),
                                   rtol=0, atol=1e-15)


def test_aggregate_weight_zero_silo_bit_exact():
    """A weight-0 silo contributes nothing, bit-exactly: zeroing silo
    j's weight gives the same aggregate as padding silo j's indices
    out of the payload entirely."""
    with enable_x64():
        comp = TopK(k=17)
        shape = (12, 12)
        _, pay = _stacked_payloads(comp, shape)
        w = jnp.asarray([1.0, 0.7, 0.0, 1.0, 0.3])
        dropped = SparsePayload(
            values=pay.values, universe=pay.universe,
            indices=pay.indices.at[2].set(-1))
        w_one = w.at[2].set(1.0)  # padding drops silo 2 regardless
        out = comp.aggregate(pay, shape, weights=w)
        ref = comp.aggregate(dropped, shape, weights=w_one)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_sharded_scatter_accumulate_four_devices():
    """The mesh-sharded accumulator on 4 forced host devices: each
    device scatters only its owned row window, and the gathered result
    equals the unsharded scatter EXACTLY — plain, and symmetric via the
    pre-shard mirror expansion. Subprocess so the forced device count
    doesn't leak into this session."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        jax.config.update("jax_enable_x64", True)
        from jax.sharding import PartitionSpec as P
        from repro.kernels.scatter_accum import (
            mirror_expand_pairs, scatter_accumulate,
            sharded_scatter_accumulate)
        from repro.launch.sharding import accumulator_spec

        mesh = jax.make_mesh((4,), ("data",))
        shape = (16, 16)
        ks = jax.random.split(jax.random.PRNGKey(0), 2)
        vals = jax.random.normal(ks[0], (6, 20), dtype=jnp.float64)
        idx = jax.random.randint(ks[1], (6, 20), 0, 256, dtype=jnp.int32)
        idx = idx.at[:, -3:].set(-1)   # wire padding stays inert
        idx = idx.at[4].set(-1)        # one dropped silo

        out = sharded_scatter_accumulate(vals, idx, shape, mesh)
        ref = scatter_accumulate(vals, idx, shape)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

        sym = sharded_scatter_accumulate(vals, idx, shape, mesh,
                                         symmetric=True)
        mv, mi = mirror_expand_pairs(vals, idx, 16)
        np.testing.assert_array_equal(
            np.asarray(sym), np.asarray(scatter_accumulate(mv, mi, shape)))
        base = np.asarray(ref)
        two_pass = base + base.T - np.diag(np.diag(base))
        np.testing.assert_allclose(np.asarray(sym), two_pass,
                                   rtol=0, atol=1e-14)

        spec = accumulator_spec(mesh, shape)
        assert spec.spec == P("data", None), spec.spec
        rep = accumulator_spec(mesh, (15, 16))   # 15 % 4 != 0: replicate
        assert rep.spec == P(None, None), rep.spec
        try:
            sharded_scatter_accumulate(vals, idx, (15, 16), mesh)
        except ValueError:
            pass
        else:
            raise AssertionError("15-row accumulator must refuse 4-way")
        print("SHARDED_SCATTER_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_SCATTER_OK" in out.stdout, out.stdout + out.stderr
