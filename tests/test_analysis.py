"""The static-analysis framework analyzes programs; these tests analyze
the analyzer: every rule must flag its deliberately-broken fixture (and
ONLY that rule must fire), every documented-legitimate pattern must
pass, and the full registry sweep must be violation-free — the pin that
turns the ISSUE's acceptance criterion into a tier-1 test."""

import jax
import jax.numpy as jnp
import pytest

from repro import analysis
from repro.analysis import Target, get_rule
from repro.core.compressors import Compressor, TopK

_ALL_JAXPR_RULES = ["no-dense-silo-stack", "no-dense-roundtrip",
                    "dtype-discipline", "no-host-sync",
                    "padding-sentinel", "vmem-budget"]


def _only(violations, rule):
    """The fixture is flagged by exactly the intended rule."""
    assert violations, f"expected {rule} to fire"
    assert {v.rule for v in violations} == {rule}


# -- framework ----------------------------------------------------------------


def test_check_raises_analysis_error_with_violations():
    def bad(x):
        jax.debug.print("x={x}", x=x)
        return x * 2

    with pytest.raises(analysis.AnalysisError) as ei:
        analysis.check(bad, jnp.ones(4), rules=["no-host-sync"])
    assert ei.value.violations
    assert "no-host-sync" in str(ei.value)


def test_unknown_rule_is_a_loud_error():
    with pytest.raises(KeyError, match="unknown rule"):
        analysis.check(lambda x: x, jnp.ones(3), rules=["no-such-rule"])


def test_rules_registered():
    for name in _ALL_JAXPR_RULES + ["no-deprecated-accessor"]:
        assert name in analysis.available_rules()
        assert get_rule(name).description


# -- no-dense-silo-stack ------------------------------------------------------


def _stacked_payload_struct(comp, n, shape):
    m = jax.ShapeDtypeStruct((n,) + shape, jnp.result_type(float))
    keys = jax.ShapeDtypeStruct((n, 2), jnp.uint32)
    return jax.eval_shape(jax.vmap(comp.compress), m, keys)


def test_dense_decompress_then_mean_aggregate_is_flagged():
    """The generic ``Compressor.aggregate`` fallback decompresses each
    silo and means the (n, d, d) stack — exactly what the rule exists
    to keep out of registered fast paths."""
    comp = TopK(k=5)
    n, shape = 3, (16, 16)
    pay = _stacked_payload_struct(comp, n, shape)
    violations = analysis.check(
        lambda p: Compressor.aggregate(comp, p, shape), pay,
        rules=_ALL_JAXPR_RULES, kind="aggregate",
        context={"silo_axis": n, "dense_shape": shape},
        raise_on_violation=False)
    _only(violations, "no-dense-silo-stack")


def test_payload_space_aggregate_passes():
    comp = TopK(k=5)
    n, shape = 3, (16, 16)
    pay = _stacked_payload_struct(comp, n, shape)
    analysis.check(lambda p: comp.aggregate(p, shape), pay,
                   rules=_ALL_JAXPR_RULES, kind="aggregate",
                   context={"silo_axis": n, "dense_shape": shape})


def test_silo_stack_reduction_in_step_is_flagged():
    """Outside aggregate targets the rule flags (n, d, d) -> (d, d)
    *reductions* (decompress-then-mean server math), while device-side
    (n, d, d) arrays themselves stay legal."""
    n, d = 3, 16

    def bad_step(h_stack):
        return jnp.mean(h_stack, axis=0)  # the server's dense mean

    violations = analysis.check(
        bad_step, jnp.ones((n, d, d)), rules=["no-dense-silo-stack"],
        kind="method-step", context={"silo_axis": n, "dense_shape": (d, d)},
        raise_on_violation=False)
    _only(violations, "no-dense-silo-stack")

    def ok_step(h_stack):
        return h_stack * 2.0 + 1.0  # per-silo state update: legal

    analysis.check(ok_step, jnp.ones((n, d, d)),
                   rules=["no-dense-silo-stack"], kind="method-step",
                   context={"silo_axis": n, "dense_shape": (d, d)})


# -- no-dense-roundtrip -------------------------------------------------------


def test_blocksq_intermediate_is_flagged():
    block = 8

    def bad(tiles):  # dense (nblocks, block^2) selection mask
        return jnp.abs(tiles.reshape(4, block * block))

    violations = analysis.check(bad, jnp.ones((16, block * block // 4)),
                                rules=_ALL_JAXPR_RULES,
                                context={"block": block},
                                raise_on_violation=False)
    _only(violations, "no-dense-roundtrip")


# -- dtype-discipline ---------------------------------------------------------


def test_f64_laundered_through_f32_is_flagged():
    with jax.experimental.enable_x64():
        def bad(x):
            y = x.astype(jnp.float32)  # silent precision loss
            return (y * 2.0).astype(jnp.float64)  # laundered back

        violations = analysis.check(bad, jnp.ones(8, jnp.float64),
                                    rules=_ALL_JAXPR_RULES,
                                    raise_on_violation=False)
        _only(violations, "dtype-discipline")


def test_selection_only_downcast_passes():
    """BlockTopKThreshold's documented pattern: f32 is fine for
    *selecting* indices (the taint dies at the bool/int boundary) as
    long as the selected values come from the f64 original."""
    with jax.experimental.enable_x64():
        def ok(x):
            score = jnp.abs(x).astype(jnp.float32)
            _, idx = jax.lax.top_k(score, 3)
            return x[idx]  # values stay f64 end to end

        analysis.check(ok, jnp.ones(8, jnp.float64), rules=_ALL_JAXPR_RULES)


# -- no-host-sync -------------------------------------------------------------


def test_host_callback_is_flagged():
    def bad(x):
        jax.debug.print("step {x}", x=x[0])
        return x + 1

    violations = analysis.check(bad, jnp.ones(4), rules=_ALL_JAXPR_RULES,
                                raise_on_violation=False)
    _only(violations, "no-host-sync")


# -- padding-sentinel ---------------------------------------------------------


def test_unremapped_negative_index_scatter_is_flagged():
    """A payload index stream fed straight into a drop-mode scatter:
    jax wraps -1 to n-1 BEFORE the bounds check, so the padding
    silently overwrites the last slot — the rule must catch it."""
    n = 16

    def bad(vals, idx):
        return jnp.zeros((n,), vals.dtype).at[idx].add(vals, mode="drop")

    violations = analysis.check(
        bad, jnp.ones(4), jnp.zeros(4, jnp.int32),
        rules=_ALL_JAXPR_RULES, raise_on_violation=False)
    _only(violations, "padding-sentinel")


def test_remapped_scatter_passes():
    n = 16

    def ok(vals, idx):
        idx = jnp.where(idx < 0, n, idx)  # sentinel out of range FIRST
        return jnp.zeros((n,), vals.dtype).at[idx].add(vals, mode="drop")

    analysis.check(ok, jnp.ones(4), jnp.zeros(4, jnp.int32),
                   rules=_ALL_JAXPR_RULES)


def test_in_trace_topk_indices_pass():
    """Indices born from top_k inside the trace cannot be -1: no remap
    required (compress->decompress fused in one step must stay legal)."""
    def ok(x):
        v, idx = jax.lax.top_k(x, 3)
        return jnp.zeros_like(x).at[idx].add(v, mode="drop")

    analysis.check(ok, jnp.ones(8), rules=_ALL_JAXPR_RULES)


# -- vmem-budget --------------------------------------------------------------


def _copy_kernel_call(dim):
    import jax.experimental.pallas as pl

    def kern(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    return pl.pallas_call(
        kern,
        out_shape=jax.ShapeDtypeStruct((dim, dim), jnp.float32),
        in_specs=[pl.BlockSpec((dim, dim), lambda: (0, 0))],
        out_specs=pl.BlockSpec((dim, dim), lambda: (0, 0)),
        interpret=True)


def test_over_budget_blockspec_is_flagged():
    """A (2048, 2048) f32 block is 16 MiB; in + out blocks put 32 MiB
    in VMEM against the 8 MiB dispatch budget — caught at trace time."""
    violations = analysis.check(
        _copy_kernel_call(2048), jnp.ones((2048, 2048), jnp.float32),
        rules=_ALL_JAXPR_RULES, raise_on_violation=False)
    _only(violations, "vmem-budget")


def test_within_budget_blockspec_passes():
    analysis.check(_copy_kernel_call(512),
                   jnp.ones((512, 512), jnp.float32),
                   rules=_ALL_JAXPR_RULES)


# -- no-deprecated-accessor (source rule) -------------------------------------


def _run_source_rule(tmp_path, text):
    p = tmp_path / "fixture.py"
    p.write_text(text)
    t = Target(name="fixture", kind="source", trace=lambda: p,
               rules=("no-deprecated-accessor",))
    return get_rule("no-deprecated-accessor").check(p, t)


def test_deprecated_accessors_are_flagged(tmp_path):
    violations = _run_source_rule(tmp_path, (
        "def f(comp, payload):\n"
        "    a = comp.bits((4, 4))\n"
        "    b = comp.spec((4, 4)).bits\n"
        "    c = payload_bits(comp, (4, 4))\n"
        "    d = payload.bits(index_coding='entropy')\n"
        "    return a + b + c + d\n"))
    assert len(violations) == 4
    assert {v.rule for v in violations} == {"no-deprecated-accessor"}


def test_live_bits_fields_and_reexports_pass(tmp_path):
    """``cell.bits`` (a live record field) and ``payload_bits``
    re-export imports must NOT trip the rule — only the quartet's
    usage patterns do."""
    violations = _run_source_rule(tmp_path, (
        "from repro.core.compressors import payload_bits\n"
        "__all__ = ['payload_bits']\n"
        "def f(cell):\n"
        "    return cell.bits[0] + float(cell.bits[-1])\n"))
    assert violations == []


# -- the registry sweep pin ---------------------------------------------------


def test_full_registry_sweep_has_zero_violations():
    """The ISSUE acceptance criterion as a test: every registered
    method x compressor step, every aggregate path, all five kernel
    packages, the precond TPU path, and the source sweep — zero
    violations. A target whose trace breaks surfaces here as an
    ``analysis-error`` violation, so registry rot fails loudly too."""
    results = analysis.analyze()
    assert len(results) > 100  # the sweep actually enumerated the world
    failures = [(t.name, [str(v) for v in vs]) for t, vs in results if vs]
    assert failures == []


def test_train_step_targets_registered():
    """The full fednl train step (fisher AND hvp curvature) is a sweep
    target, carrying every jaxpr data-path rule — so a regression in
    ``make_train_step``'s observation phase fails the registry sweep,
    not just the unit tests."""
    targets = analysis.iter_targets(["train-step"])
    names = {t.name for t in targets}
    assert names == {"train-step:fednl[fisher]", "train-step:fednl[hvp]"}
    for t in targets:
        assert t.kind == "train-step"
        for rule in ("no-dense-silo-stack", "no-dense-roundtrip",
                     "dtype-discipline", "vmem-budget"):
            assert rule in t.rules, (t.name, rule)
        assert t.context["block"] == 128
