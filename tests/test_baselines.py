"""Baselines converge and their accounting is sane."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import RandomDithering
from repro.core.baselines import (
    NL1,
    Adiana,
    Artemis,
    Diana,
    Dingo,
    Dore,
    gd_ls_run,
    gd_run,
)
from repro.core.newton import newton_run
from repro.core.objectives import (
    batch_grad,
    batch_hess,
    global_value,
    lipschitz_constants,
)
from repro.data.synthetic import make_synthetic


@pytest.fixture(scope="module")
def prob():
    data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                          n=8, m=50, d=20, lam=1e-3)
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    val_fn = lambda x: global_value(x, data)
    xstar, _ = newton_run(jnp.zeros(20), grad_fn, hess_fn, 30)
    return dict(data=data, grad=grad_fn, hess=hess_fn, val=val_fn,
                xstar=xstar, fstar=float(val_fn(xstar)),
                L=lipschitz_constants(data)["L"])


def _gap(prob, x):
    return float(prob["val"](x)) - prob["fstar"]


def test_gd(prob):
    x0 = jnp.ones(20)
    final, _ = gd_run(x0, prob["grad"], 1.0 / prob["L"], 300)
    assert _gap(prob, final) < 0.1 * _gap(prob, x0)


def test_gd_ls_beats_gd(prob):
    x0 = jnp.ones(20)
    f1, _ = gd_run(x0, prob["grad"], 1.0 / prob["L"], 100)
    f2, _ = gd_ls_run(x0, prob["val"], prob["grad"], 100)
    assert _gap(prob, f2) <= _gap(prob, f1) * 1.05


def test_diana(prob):
    rd = RandomDithering(s=4)
    om = rd.spec((20,)).omega
    alg = Diana(prob["grad"], rd, prob["L"], 8, om)
    final, _ = alg.run(jnp.ones(20), 8, 500)
    assert _gap(prob, final.x) < 0.05 * _gap(prob, jnp.ones(20))


def test_adiana_converges(prob):
    rd = RandomDithering(s=4)
    om = rd.spec((20,)).omega
    alg = Adiana(prob["grad"], rd, prob["L"], 1e-3, 8, om)
    final, _ = alg.run(jnp.ones(20), 8, 800)
    assert _gap(prob, final.y) < 0.2 * _gap(prob, jnp.ones(20))


def test_dingo_gradient_norm_decreases(prob):
    alg = Dingo(prob["val"], prob["grad"], prob["hess"])
    _, xs = alg.run(jnp.ones(20), 30)
    g0 = float(jnp.linalg.norm(jnp.mean(prob["grad"](xs[0]), 0)))
    gT = float(jnp.linalg.norm(jnp.mean(prob["grad"](xs[-1]), 0)))
    assert gT < 0.1 * g0


def test_nl1_local(prob):
    x0 = prob["xstar"] + 0.05 * jax.random.normal(jax.random.PRNGKey(1), (20,))
    alg = NL1(prob["data"], k=3)
    final, _ = alg.run(x0, 80)
    assert _gap(prob, final.x) < 1e-6


def test_dore_and_artemis(prob):
    rd = RandomDithering(s=4)
    om = rd.spec((20,)).omega
    dore = Dore(prob["grad"], rd, rd, prob["L"], 8, om, om)
    f1, _ = dore.run(jnp.ones(20), 8, 500)
    assert _gap(prob, f1.x) < 0.1 * _gap(prob, jnp.ones(20))

    art = Artemis(prob["grad"], rd, prob["L"], 8, om, tau=4)
    f2, _ = art.run(jnp.ones(20), 8, 500)
    assert _gap(prob, f2.x) < 0.15 * _gap(prob, jnp.ones(20))


def test_bits_per_round_ordering(prob):
    """FedNL with Rank-1 moves O(d) floats; Newton moves O(d^2)."""
    from repro.core import FedNL, Identity, RankR

    d = 20
    fednl = FedNL(prob["grad"], prob["hess"], RankR(1))
    newton_like = FedNL(prob["grad"], prob["hess"], Identity())
    assert fednl.bits_per_round(d) < newton_like.bits_per_round(d) / 5
