"""Cross-device cohort layer tests: CohortSpec validation, K-of-N
sampling determinism, staleness-weight edge cases, the FedNL-PP
recovery guarantee (beta = 0, deadline_quantile = 1 reproduces FedNL-PP
with tau = cohort bitwise), and the ExperimentSpec/Sweep plumbing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import CohortSpec, FedNLPP, TopK
from repro.core.cohort import (
    CohortFedNLPP,
    arrival_times,
    on_time_mask,
    sample_cohort,
    staleness_weights,
)
from repro.core.objectives import batch_grad, batch_hess, global_value
from repro.data.synthetic import make_synthetic
from repro.engine import ExperimentSpec, Sweep

D, N = 10, 6


@pytest.fixture(scope="module")
def problem():
    with enable_x64():
        data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                              n=N, m=30, d=D, lam=1e-3)
        data = data._replace(a=data.a.astype(jnp.float64),
                             b=data.b.astype(jnp.float64))
        yield dict(data=data,
                   grad=lambda x: batch_grad(x, data),
                   hess=lambda x: batch_hess(x, data),
                   val=lambda x: global_value(x, data),
                   n=N, d=D, fstar=0.0)


# -- CohortSpec ----------------------------------------------------------------


@pytest.mark.parametrize("kwargs", [
    dict(cohort=0),
    dict(cohort=3, population=2),
    dict(cohort=1, deadline_quantile=0.0),
    dict(cohort=1, deadline_quantile=1.5),
    dict(cohort=1, staleness_beta=-0.1),
])
def test_cohort_spec_rejects_bad_config(kwargs):
    with pytest.raises(ValueError):
        CohortSpec(**kwargs)


def test_cohort_spec_defaults_are_cross_device():
    spec = CohortSpec(cohort=100, population=10_000)
    assert spec.link == "fl-cross-device"
    assert 0.0 < spec.deadline_quantile <= 1.0
    assert spec.staleness_beta >= 0.0


# -- sampling / arrival / staleness -------------------------------------------


def test_sample_cohort_exactly_k_and_deterministic():
    key = jax.random.PRNGKey(7)
    mask = sample_cohort(key, 50, 10)
    assert mask.shape == (50,) and mask.dtype == jnp.bool_
    assert int(mask.sum()) == 10
    np.testing.assert_array_equal(np.asarray(mask),
                                  np.asarray(sample_cohort(key, 50, 10)))
    other = sample_cohort(jax.random.PRNGKey(8), 50, 10)
    assert not bool(jnp.array_equal(mask, other))
    # K >= N degenerates to everyone
    assert int(sample_cohort(key, 4, 9).sum()) == 4


def test_staleness_weights_edge_cases():
    s = jnp.asarray([0, 1, 3, 7])
    # beta = 0: no discount at any staleness (the FedNL-PP recovery)
    np.testing.assert_array_equal(np.asarray(staleness_weights(s, 0.0)),
                                  np.ones(4))
    w = np.asarray(staleness_weights(s, 0.5))
    assert w[0] == 1.0                       # fresh client: full weight
    assert np.all(np.diff(w) < 0)            # strictly decaying
    np.testing.assert_allclose(w[2], 0.5)    # (1 + 3)^(-1/2)
    # negative staleness (never-sampled init) clamps to fresh
    assert float(staleness_weights(jnp.asarray(-2), 0.5)) == 1.0


def test_arrival_times_deterministic_and_deadline():
    spec = CohortSpec(cohort=8, population=32, seed=3)
    t1 = arrival_times(spec, 32, bits_per_silo=1e6)
    t2 = arrival_times(spec, 32, bits_per_silo=1e6)
    np.testing.assert_array_equal(t1, t2)
    assert t1.shape == (32,) and np.all(t1 > 0)
    assert bool(np.all(on_time_mask(t1, 1.0)))       # quantile 1: everyone
    frac = float(np.mean(on_time_mask(t1, 0.5)))     # median deadline
    assert 0.25 <= frac <= 0.75


# -- CohortFedNLPP -------------------------------------------------------------


def test_cohort_recovers_fednl_pp_bitwise(problem):
    """beta = 0 + deadline_quantile = 1 is FedNL-PP with tau = cohort:
    identical key usage, unit weights for the sampled cohort — the two
    trajectories must agree BITWISE round for round."""
    with enable_x64():
        comp = TopK(k=20)
        x0 = jnp.zeros(D, jnp.float64)
        pp = FedNLPP(problem["grad"], problem["hess"], comp, tau=2)
        spec = CohortSpec(cohort=2, staleness_beta=0.0,
                          deadline_quantile=1.0)
        co = CohortFedNLPP(problem["grad"], problem["hess"], comp,
                           cohort=spec)
        _, xs_pp = pp.run(x0, N, 6)
        _, xs_co = co.run(x0, N, 6)
        np.testing.assert_array_equal(np.asarray(xs_co), np.asarray(xs_pp))


def test_cohort_straggler_discount_applied(problem):
    """With an aggressive deadline and beta > 0, sampled stragglers get
    exactly the (1 + staleness)^(-beta) weight and unsampled silos get
    0 — checked against the hand-computed arrival mask."""
    with enable_x64():
        spec = CohortSpec(cohort=4, staleness_beta=0.5,
                          deadline_quantile=0.5, seed=1)
        co = CohortFedNLPP(problem["grad"], problem["hess"], TopK(k=20),
                           cohort=spec)
        state = co.init(jnp.zeros(D, jnp.float64), N)
        state = state._replace(step=state.step + 3)  # 3 rounds stale
        active = jnp.asarray([True, True, True, False, False, True])
        wts = np.asarray(co._round_weights(state, active))
        from repro.wire import wire_cost

        bits = wire_cost(co.comp, (D, D), encoded=False).analytic_bits
        on_time = on_time_mask(arrival_times(spec, N, bits),
                               spec.deadline_quantile)
        assert np.all(wts[~np.asarray(active)] == 0.0)
        late = np.asarray(active) & ~on_time
        np.testing.assert_allclose(wts[late], (1 + 3) ** -0.5)
        assert np.all(wts[np.asarray(active) & on_time] == 1.0)


def test_cohort_population_mismatch_raises(problem):
    spec = CohortSpec(cohort=2, population=4)   # problem has n = 6
    co = CohortFedNLPP(problem["grad"], problem["hess"], TopK(k=20),
                       cohort=spec)
    with pytest.raises(ValueError, match="population"):
        co.init(jnp.zeros(D), N)


def test_cohort_converges_and_is_deterministic(problem):
    with enable_x64():
        spec = CohortSpec(cohort=3, population=N)
        co = CohortFedNLPP(problem["grad"], problem["hess"], TopK(k=30),
                           cohort=spec, alpha=1.0)
        x0 = jnp.zeros(D, jnp.float64)
        _, xs1 = co.run(x0, N, 60)
        _, xs2 = co.run(x0, N, 60)
        np.testing.assert_array_equal(np.asarray(xs1), np.asarray(xs2))
        # drives the GLOBAL gradient to (near) zero despite sampling +
        # straggler discounts; the objective itself plateaus at f* > 0
        gnorm = [float(jnp.linalg.norm(jnp.mean(problem["grad"](x), 0)))
                 for x in xs1]
        assert gnorm[-1] < 1e-8 * gnorm[0]
        assert gnorm[-1] < 1e-9


# -- engine plumbing -----------------------------------------------------------


def test_experiment_spec_cohort_through_sweep(problem):
    """ONE CohortSpec drives the whole cell: the method construction,
    the display label, and the traffic-model pricing (cohort link +
    cohort size, not the sweep-wide preset)."""
    with enable_x64():
        spec = ExperimentSpec("fednl-cohort", "topk", 20,
                              cohort=CohortSpec(cohort=3, population=N),
                              num_rounds=8)
        assert spec.label == "fednl-cohort:topk20:K3ofN6"
        res = Sweep([spec]).run(problem, x0=jnp.zeros(D, jnp.float64))
        cell = res.cells[0]
        assert cell.xs.shape == (1, 9, D)
        assert np.all(np.isfinite(cell.xs))
        assert cell.gaps[0, -1] < cell.gaps[0, 1]
        assert cell.seconds_per_round is not None
        assert cell.seconds_per_round > 0.0
