"""Hypothesis property tests for the compression operators (Definitions
3.2 / 3.3): contraction / unbiasedness inequalities and fuzzed payload
round-trips (bit-identical to the seed-era dense operators, pinned here
as references). The no-optional-deps payload/registry tests live in
test_payloads.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional [test] extra")
from hypothesis import given, settings, strategies as st

from _dense_refs import (
    blocktopk_dense_ref,
    randk_dense_ref,
    rankr_dense_ref,
    topk_dense_ref,
)
from repro.core.compressors import (
    BlockTopK,
    BlockTopKThreshold,
    Identity,
    NaturalSparsification,
    PowerSGD,
    RandK,
    RandomDithering,
    RankR,
    TopK,
    Zero,
    ab_constants,
    alpha_for,
)

DIMS = st.integers(min_value=2, max_value=24)


def _rand(seed, d0, d1):
    return jax.random.normal(jax.random.PRNGKey(seed), (d0, d1))


def _check_contractive(comp, m, delta):
    c = comp(m)
    nm = float(jnp.linalg.norm(m))
    nc = float(jnp.linalg.norm(c))
    err = float(jnp.linalg.norm(c - m)) ** 2
    assert nc <= nm * (1 + 1e-5), "||C(M)||_F <= ||M||_F violated"
    assert err <= (1 - delta) * nm**2 + 1e-5 * nm**2, \
        f"contraction violated: {err} > (1-{delta}) {nm**2}"


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, kfrac=st.floats(0.05, 1.0))
def test_topk_contractive(seed, d, kfrac):
    m = _rand(seed, d, d)
    k = max(1, int(kfrac * d * d))
    comp = TopK(k=k)
    _check_contractive(comp, m, comp.spec((d, d)).delta)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, r=st.integers(1, 6))
def test_rankr_contractive_symmetric(seed, d, r):
    m = _rand(seed, d, d)
    m = 0.5 * (m + m.T)  # FedNL compresses Hessian differences (symmetric)
    comp = RankR(r=min(r, d))
    _check_contractive(comp, m, comp.spec((d, d)).delta)
    # output is symmetric, as A.3.2 notes
    c = comp(m)
    np.testing.assert_allclose(c, c.T, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, r=st.integers(1, 6))
def test_rankr_contractive_general(seed, d, r):
    m = _rand(seed, d, d)
    comp = RankR(r=min(r, d), symmetric=False)
    _check_contractive(comp, m, comp.spec((d, d)).delta)


def test_rankr_symmetric_matches_svd():
    m = _rand(7, 12, 12)
    m = 0.5 * (m + m.T)
    a = RankR(r=3, symmetric=True)(m)
    b = RankR(r=3, symmetric=False)(m)
    np.testing.assert_allclose(a, b, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(4, 20), r=st.integers(1, 3))
def test_powersgd_contractive(seed, d, r):
    m = _rand(seed, d, d)
    comp = PowerSGD(r=r, iters=2)
    # PowerSGD is rescaled to be in C(delta) for SOME delta >= 0;
    # the first inequality must hold exactly, the second with delta = 0.
    _check_contractive(comp, m, 0.0)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), kb=st.integers(1, 16))
def test_block_topk_contractive(seed, kb):
    m = _rand(seed, 8, 12)
    comp = BlockTopK(k_per_block=kb, block=4)
    _check_contractive(comp, m, comp.spec((8, 12)).delta)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), kb=st.integers(1, 16))
def test_block_topk_threshold_contractive(seed, kb):
    m = _rand(seed, 8, 12)
    comp = BlockTopKThreshold(k_per_block=kb, block=4)
    _check_contractive(comp, m, comp.spec((8, 12)).delta)


def test_topk_keeps_largest():
    m = jnp.asarray([[1.0, -5.0], [3.0, 0.5]])
    out = TopK(k=2)(m)
    np.testing.assert_allclose(out, [[0.0, -5.0], [3.0, 0.0]])


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_randk_unbiased(seed):
    d = 6
    m = _rand(seed, d, d)
    comp = RandK(k=9)
    keys = jax.random.split(jax.random.PRNGKey(seed), 3000)
    mean = jnp.mean(jax.vmap(lambda k: comp(m, k))(keys), axis=0)
    np.testing.assert_allclose(mean, m, atol=0.25)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_randk_variance_bound(seed):
    d = 6
    m = _rand(seed, d, d)
    comp = RandK(k=9)
    omega = comp.spec((d, d)).omega
    keys = jax.random.split(jax.random.PRNGKey(seed + 77), 2000)
    errs = jax.vmap(lambda k: jnp.sum((comp(m, k) - m) ** 2))(keys)
    assert float(jnp.mean(errs)) <= omega * float(jnp.sum(m**2)) * 1.1


def test_dithering_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (32,))
    comp = RandomDithering(s=4)
    keys = jax.random.split(jax.random.PRNGKey(1), 4000)
    mean = jnp.mean(jax.vmap(lambda k: comp(x, k))(keys), axis=0)
    np.testing.assert_allclose(mean, x, atol=0.05)


def test_bernoulli_unbiased():
    x = jax.random.normal(jax.random.PRNGKey(0), (64,))
    comp = NaturalSparsification(p=0.3)
    keys = jax.random.split(jax.random.PRNGKey(1), 8000)
    mean = jnp.mean(jax.vmap(lambda k: comp(x, k))(keys), axis=0)
    np.testing.assert_allclose(mean, x, atol=0.25)  # ~5 sigma


def test_identity_zero():
    m = _rand(0, 5, 5)
    np.testing.assert_allclose(Identity()(m), m)
    np.testing.assert_allclose(Zero()(m), jnp.zeros_like(m))


def test_alpha_rules():
    d = 10
    comp = TopK(k=20)
    assert alpha_for(comp, (d, d), "one") == 1.0
    a = alpha_for(comp, (d, d), "contract")
    delta = comp.spec((d, d)).delta
    assert abs(a - (1 - (1 - delta) ** 0.5)) < 1e-12
    rk = RandK(k=20)
    au = alpha_for(rk, (d, d), "auto")
    assert abs(au - 1.0 / (1 + rk.spec((d, d)).omega)) < 1e-12


def test_ab_constants_match_eq5():
    d = 10
    comp = TopK(k=20)
    delta = comp.spec((d, d)).delta
    a, b = ab_constants(comp, (d, d), alpha=1.0)
    assert abs(a - delta / 4) < 1e-12 and abs(b - (6 / delta - 3.5)) < 1e-12
    a, b = ab_constants(comp, (d, d), alpha=1 - (1 - delta) ** 0.5)
    al = 1 - (1 - delta) ** 0.5
    assert abs(a - al**2) < 1e-12 and abs(b - al) < 1e-12


def test_bits_accounting():
    assert TopK(k=10).bits((8, 8)) == 10 * (64 + 32)
    assert RankR(r=2).bits((8, 8)) == 2 * 64 * (1 + 16)
    assert RandK(k=5).bits((8, 8)) == 5 * (64 + 32)
    assert Zero().bits((8, 8)) == 0


# -- payload wire-format round-trips (fuzzed) ---------------------------------
# decompress(compress(M)) must be BIT-IDENTICAL to the seed-era dense
# operators (re-implemented here as pinned references), for every family.


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, k=st.integers(1, 600))
def test_topk_roundtrip_bit_identical(seed, d, k):
    m = _rand(seed, d, d)
    comp = TopK(k=k)
    out = comp.decompress(comp.compress(m), m.shape)
    assert np.array_equal(np.asarray(out), np.asarray(topk_dense_ref(m, k)))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, k=st.integers(1, 600))
def test_topk_symmetric_roundtrip_bit_identical(seed, d, k):
    m = _rand(seed, d, d)
    comp = TopK(k=k, symmetric=True)
    out = comp.decompress(comp.compress(m), m.shape)
    ref = topk_dense_ref(m, k, symmetric=True)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), k=st.integers(1, 40))
def test_randk_roundtrip_bit_identical(seed, k):
    m = _rand(seed, 7, 9)
    key = jax.random.PRNGKey(seed + 1)
    comp = RandK(k=k)
    out = comp.decompress(comp.compress(m, key), m.shape)
    assert np.array_equal(np.asarray(out),
                          np.asarray(randk_dense_ref(m, k, key)))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), kb=st.integers(1, 20))
def test_blocktopk_roundtrip_bit_identical(seed, kb):
    m = _rand(seed, 10, 14)
    comp = BlockTopK(k_per_block=kb, block=4)
    out = comp.decompress(comp.compress(m), m.shape)
    ref = blocktopk_dense_ref(m, kb, 4)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 10_000), d=DIMS, r=st.integers(1, 6))
def test_rankr_roundtrip_bit_identical(seed, d, r):
    m = _rand(seed, d, d)
    m = 0.5 * (m + m.T)
    comp = RankR(r=min(r, d))
    out = comp.decompress(comp.compress(m), m.shape)
    assert np.array_equal(np.asarray(out),
                          np.asarray(rankr_dense_ref(m, min(r, d))))


# The registry-wide Def 3.3 / 3.2 sweep lives in test_payloads.py (it
# needs no optional deps, so it runs even without hypothesis).
