"""Engine contract tests: registry round-trip, vmapped sweeps vs serial
runs, and bits accounting pinned to the seed-era (pre-refactor) values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import FedNL, FedNLBC, FedNLCR, FedNLLS, FedNLPP, RankR, TopK
from repro.core.objectives import batch_grad, batch_hess, global_value
from repro.data.synthetic import make_synthetic
from repro.engine import (
    ExperimentSpec,
    Oracles,
    Sweep,
    available_methods,
    build_compressor,
    make_method,
)

D, N = 12, 8


@pytest.fixture(scope="module")
def problem():
    with enable_x64():
        data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                              n=N, m=40, d=D, lam=1e-3)
        data = data._replace(a=data.a.astype(jnp.float64),
                             b=data.b.astype(jnp.float64))
        grad_fn = lambda x: batch_grad(x, data)
        hess_fn = lambda x: batch_hess(x, data)
        val_fn = lambda x: global_value(x, data)
        yield dict(data=data, grad=grad_fn, hess=hess_fn, val=val_fn,
                   n=N, d=D, fstar=0.0)


def _oracles(problem):
    return Oracles(value=problem["val"], grad=problem["grad"],
                   hess=problem["hess"])


# Per-method construction params for the registry round-trip. Every key
# of available_methods() must appear here — a new method without a
# working factory fails this test.
def _roundtrip_params(d):
    from repro.core import CohortSpec

    topk = ("topk", d)
    return {
        "fednl": dict(option=1, mu=1e-3),
        "fednl-pp": dict(tau=2),
        "fednl-cohort": dict(cohort=CohortSpec(cohort=3)),
        "fednl-cr": dict(l_star=1.0),
        "fednl-ls": dict(mu=1e-3),
        "fednl-bc": dict(model_compressor=topk, p=0.9, option=1, mu=1e-3),
        "fednl-ppbc": dict(model_compressor=topk, tau=2),
        "fednl-stoch": dict(alpha=0.5),
        "newton": dict(),
        "ns": dict(h_fixed=jnp.eye(d)),
        "n0": dict(mu=1e-3),
        "n0-ls": dict(mu=1e-3),
    }


def test_registry_round_trip(problem):
    """Every registered method is constructible by name and survives a
    2-round run through the shared driver."""
    with enable_x64():
        params = _roundtrip_params(D)
        x0 = jnp.zeros(D, jnp.float64)
        comp = build_compressor("rankr", 1)
        missing = [m for m in available_methods() if m not in params]
        assert not missing, f"no round-trip params for {missing}"
        for name in available_methods():
            method = make_method(name, _oracles(problem), comp, **params[name])
            final, xs = method.run(x0, N, 2)
            assert xs.shape == (3, D), (name, xs.shape)
            assert bool(jnp.all(jnp.isfinite(xs))), name
            assert np.asarray(xs[0] == x0).all(), name  # x0 prepended
            # the full Method protocol, not just run(): a registered
            # method without bits accounting would crash every Sweep
            b = method.bits_per_round(D)
            assert (sum(b) if isinstance(b, tuple) else b) >= 0, name


def test_make_method_unknown_name(problem):
    with pytest.raises(KeyError, match="unknown method"):
        make_method("not-a-method", _oracles(problem))


def test_vmapped_sweep_matches_serial_runs(problem):
    """Acceptance: a 3-seed x 4-level fig3-style sweep runs as one
    vmapped jitted program per cell and matches per-seed serial results
    to float64 tolerance.

    Not bitwise: batched eigh/svd take different LAPACK paths than the
    unbatched calls (O(eps) output differences), and a far-from-x*
    transient can amplify those through compressor tie-breaks. In the
    fig3 regime (start in the local basin) the measured worst case is
    ~3e-14; 1e-10 leaves margin while staying firmly float64."""
    with enable_x64():
        x0 = jnp.zeros(D, jnp.float64)
        seeds, rounds = (0, 1, 2), 8
        specs = [ExperimentSpec("fednl", "rankr", lvl,
                                params=dict(option=1, mu=1e-3),
                                seeds=seeds, num_rounds=rounds)
                 for lvl in (1, 2, 3, 4)]
        res = Sweep(specs).run(problem, x0=x0)
        assert len(res.cells) == 4
        for cell in res.cells:
            assert cell.xs.shape == (len(seeds), rounds + 1, D)
            alg = FedNL(problem["grad"], problem["hess"],
                        RankR(int(cell.spec.level)), option=1, mu=1e-3)
            for si, seed in enumerate(seeds):
                _, xs_serial = alg.run(x0, N, rounds, seed=seed)
                np.testing.assert_allclose(cell.xs[si],
                                           np.asarray(xs_serial),
                                           rtol=0, atol=1e-10)


def test_sweep_distinct_seeds_distinct_trajectories(problem):
    """Randomized compressors must actually fold the seed in — identical
    trajectories across seeds would mean the vmap axis is dead."""
    with enable_x64():
        x0 = jnp.full((D,), 0.5, jnp.float64)
        spec = ExperimentSpec("fednl", "randk", 40,
                              params=dict(option=2, alpha=0.5),
                              seeds=(0, 1), num_rounds=4)
        cell = Sweep([spec]).run(problem, x0=x0).cells[0]
        assert np.abs(cell.xs[0, 1:] - cell.xs[1, 1:]).max() > 0


def test_sweep_records_and_summary(problem):
    with enable_x64():
        spec = ExperimentSpec("fednl", "rankr", 1,
                              params=dict(option=1, mu=1e-3),
                              seeds=(0, 1), num_rounds=3, name="cellA")
        res = Sweep([spec]).run(problem, x0=jnp.zeros(D, jnp.float64))
        rows = res.records()
        assert len(rows) == 2 * 4  # seeds x (rounds+1)
        assert {r["name"] for r in rows} == {"cellA"}
        assert rows[0]["round"] == 0 and rows[3]["round"] == 3
        summ = res.summary(target=1e30)  # everything hits a huge target
        assert summ[0]["rounds_to_target"] == 0
        assert summ[0]["us_per_round"] > 0


def test_engine_bc_records_learned_model(problem):
    """FedNL-BC's monitored trajectory is z (the learned model devices
    actually hold), not the server's uncompressed x."""
    with enable_x64():
        spec = ExperimentSpec("fednl-bc", "topk", D * D,
                              params=dict(model_compressor=("topk", D),
                                          p=1.0, option=1, mu=1e-3),
                              seeds=(0,), num_rounds=3)
        cell = Sweep([spec]).run(problem, x0=jnp.zeros(D, jnp.float64)).cells[0]
        assert cell.xs.shape == (1, 4, D)
        assert np.all(np.isfinite(cell.xs))


def test_sharded_sweep_matches_plain_single_device(problem):
    """The mesh path (core/federated.py shard_map) agrees with the vmap
    path on a trivial 1-device mesh."""
    with enable_x64():
        x0 = jnp.full((D,), 0.3, jnp.float64)
        spec = ExperimentSpec("fednl", "rankr", 1, params=dict(option=2),
                              seeds=(0,), num_rounds=4)
        mesh = jax.make_mesh((1,), ("data",))
        plain = Sweep([spec]).run(problem, x0=x0).cells[0]
        sharded = Sweep([spec], mesh=mesh).run(problem, x0=x0).cells[0]
        np.testing.assert_allclose(sharded.xs, plain.xs, rtol=0, atol=1e-10)


# -- bits accounting pinned to the seed-era formulas --------------------------
# These integers were computed from the pre-refactor implementations
# (FLOAT_BITS=64, INDEX_BITS=32, d=16, RankR(1) / TopK(16)). The engine
# refactor must not move the paper's x-axis.


def test_bits_accounting_identical_pre_post_refactor(problem):
    d = 16
    g, h, v = problem["grad"], problem["hess"], problem["val"]
    rank1 = RankR(1)
    # grad (d floats) + S_i (rank-1: 64*(1+d+d)) + l_i (1 float)
    assert FedNL(g, h, rank1).bits_per_round(d) == 3200
    assert FedNL(g, h, rank1).init_bits(d) == 8704  # d(d+1)/2 floats
    # S_i + l diff (1 float) + g diff (d floats)
    assert FedNLPP(g, h, rank1, tau=2).bits_per_round(d) == 3200
    # grad + S_i + l_i
    assert FedNLCR(g, h, rank1, l_star=1.0).bits_per_round(d) == 3200
    # f_i + grad + S_i
    assert FedNLLS(v, g, h, rank1).bits_per_round(d) == 3200
    # up: p*d floats + TopK(16) (96 bits/entry) + l_i; down: TopK(16) + xi
    up, down = FedNLBC(g, h, TopK(k=16), TopK(k=16),
                       p=0.5).bits_per_round(d)
    assert up == 0.5 * 16 * 64 + 16 * 96 + 64 == 2112.0
    assert down == 16 * 96 + 1 == 1537


def test_engine_bits_curve_matches_method_accounting(problem):
    with enable_x64():
        spec = ExperimentSpec("fednl", "rankr", 1,
                              params=dict(option=1, mu=1e-3),
                              seeds=(0,), num_rounds=3)
        cell = Sweep([spec]).run(problem, x0=jnp.zeros(D, jnp.float64)).cells[0]
        alg = FedNL(problem["grad"], problem["hess"], RankR(1))
        expect = alg.init_bits(D) + alg.bits_per_round(D) * np.arange(4)
        np.testing.assert_array_equal(cell.bits, expect)


def test_engine_measured_bits_match_analytic_under_x64(problem):
    """Acceptance: a Sweep cell reports measured per-round bits (derived
    from the payload structure) that match the analytic bits_per_round
    under x64, for the four acceptance compressor families."""
    with enable_x64():
        x0 = jnp.zeros(D, jnp.float64)
        specs = [
            ExperimentSpec("fednl", "rankr", 2,
                           params=dict(option=1, mu=1e-3), num_rounds=2),
            ExperimentSpec("fednl", "topk", D, params=dict(option=1, mu=1e-3),
                           num_rounds=2),
            ExperimentSpec("fednl", "blocktopk", 4,
                           params=dict(option=1, mu=1e-3), num_rounds=2),
            ExperimentSpec("fednl", "randk", D,
                           params=dict(option=2, alpha=0.5), num_rounds=2),
        ]
        res = Sweep(specs).run(problem, x0=x0)
        for cell in res.cells:
            np.testing.assert_array_equal(cell.bits_measured, cell.bits)
        rows = res.records()
        assert all(r["bits_measured"] == r["bits"] for r in rows)
        summ = res.summary()
        assert all(s["bits_per_round_measured"] == s["bits_per_round"] > 0
                   for s in summ)


def test_engine_measured_bits_bc_uplink_downlink(problem):
    """FedNL-BC's measured accounting covers both directions: the uplink
    Hessian payload and the downlink model payload."""
    with enable_x64():
        from repro.core import TopK
        from repro.engine import measured_bits_per_round

        alg = FedNLBC(problem["grad"], problem["hess"], TopK(k=16),
                      TopK(k=8), p=0.5)
        up, down = alg.measured_bits_per_round(16)
        up_a, down_a = alg.bits_per_round(16)
        assert (up, down) == (up_a, down_a)
        assert measured_bits_per_round(alg, 16) == up_a + down_a
