"""Beyond-paper extensions (paper Limitations, Appendix I): stochastic
Hessian oracles and the PP+BC master method."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RankR, TopK
from repro.core.extensions import FedNLPPBC, StochasticFedNL
from repro.core.newton import newton_run
from repro.core.objectives import batch_grad, batch_hess, global_value, silo_hess
from repro.data.synthetic import make_synthetic


@pytest.fixture(scope="module")
def prob():
    data = make_synthetic(jax.random.PRNGKey(0), 0.5, 0.5, n=8, m=64, d=16,
                          lam=1e-3)
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    val_fn = lambda x: global_value(x, data)
    xstar, _ = newton_run(jnp.zeros(16), grad_fn, hess_fn, 30)
    return dict(data=data, grad=grad_fn, hess=hess_fn, val=val_fn,
                xstar=xstar, fstar=float(val_fn(xstar)))


def _subsampled_hess(data, m_sub):
    """Per-round minibatch Hessian oracle: m_sub of m points per silo."""
    n, m, d = data.a.shape

    def hess(x, key):
        keys = jax.random.split(key, n)

        def one(a, b, k):
            idx = jax.random.choice(k, m, (m_sub,), replace=False)
            return silo_hess(x, a[idx], b[idx], data.lam)

        return jax.vmap(one)(data.a, data.b, keys)

    return hess


@pytest.mark.slow  # stochastic noise-floor check; long and seed-sensitive
def test_stochastic_hessian_fednl_converges(prob):
    """Exact gradients + 50%-subsampled Hessians: x* stays the fixed
    point (gradients exact), so iterates keep converging — linearly, at a
    rate set by how well the noisy learned H approximates the Hessian.

    Deflaked: the decay is slow-linear (measured 3.3e-1 -> ~7e-5 over 80
    rounds; the tail of the last 10 rounds sits under 1e-4 across
    seeds), so the check runs to 80 rounds and bounds the WORST gap of
    the tail at 2x the measured envelope instead of asserting on the
    single (noise-realization-sensitive) final iterate at 40."""
    data = prob["data"]
    hess_stoch = _subsampled_hess(data, m_sub=32)
    x0 = prob["xstar"] + 0.3 * jax.random.normal(jax.random.PRNGKey(1), (16,))
    alg = StochasticFedNL(prob["grad"], hess_stoch, RankR(2), alpha=0.5)
    final, xs = alg.run(x0, 8, 80)
    gap0 = float(prob["val"](x0)) - prob["fstar"]
    gaps = np.asarray(jax.vmap(prob["val"])(xs[-10:])) - prob["fstar"]
    assert float(gaps.max()) < 2e-3 * gap0
    assert float(gaps.max()) < 2e-4, gaps


def test_stochastic_fednl_communication_vs_newton(prob):
    """The honest comparison dimension is BITS: stochastic FedNL reaches
    the subsampling noise floor with O(d) uplink/round (rank-2 compressed
    diffs) while stochastic Newton ships the full d x d Hessian. (Plain
    stochastic Newton is NOT noisier near x* with exact gradients — a
    refuted initial hypothesis, kept here as documentation.)"""
    from repro.core import Identity
    from repro.core.compressors import FLOAT_BITS

    d = 16
    StochasticFedNL(prob["grad"], _subsampled_hess(prob["data"], 16),
                    RankR(2), alpha=0.5)  # constructs cleanly
    bits_fednl = d * FLOAT_BITS + RankR(2).bits((d, d)) + FLOAT_BITS
    bits_newton = d * FLOAT_BITS + d * d * FLOAT_BITS
    assert bits_fednl < bits_newton / 2


def test_ppbc_master_method_converges(prob):
    d = 16
    x0 = prob["xstar"] + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (d,))
    alg = FedNLPPBC(prob["grad"], prob["hess"], RankR(1),
                    model_compressor=TopK(k=int(0.9 * d)), tau=4,
                    eta=1.0)
    final, zs = alg.run(x0, 8, 120)
    gap = float(prob["val"](final.z)) - prob["fstar"]
    assert gap < 1e-7, gap  # f32 floor


def test_ppbc_full_participation_uncompressed_matches_pp(prob):
    """With tau = n and C_M = identity the master method reduces to
    FedNL-PP (sanity: specializations recover the paper's algorithms)."""
    from repro.core import FedNLPP, Identity

    d = 16
    x0 = prob["xstar"] + 0.05 * jax.random.normal(jax.random.PRNGKey(2), (d,))
    ppbc = FedNLPPBC(prob["grad"], prob["hess"], RankR(1),
                     model_compressor=Identity(), tau=8, eta=1.0)
    _, zs = ppbc.run(x0, 8, 10)
    pp = FedNLPP(prob["grad"], prob["hess"], RankR(1), tau=8)
    _, xs = pp.run(x0, 8, 10)
    # same fixed point and comparable trajectory scale
    g1 = float(prob["val"](zs[-1])) - prob["fstar"]
    g2 = float(prob["val"](xs[-1])) - prob["fstar"]
    assert g1 < 1e-7 and g2 < 1e-7  # f32 floor


def test_ppbc_bits_accounting(prob):
    d = 16
    alg = FedNLPPBC(prob["grad"], prob["hess"], RankR(1),
                    model_compressor=TopK(k=d), tau=4)
    up, down = alg.bits_per_round(d)
    assert up > 0 and down > 0
    # downlink is O(d), not O(d^2)
    assert down < d * d * 8
