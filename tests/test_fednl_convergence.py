"""Theorem-level convergence tests for the FedNL family (float64).

Long-running (many rounds at f64): marked slow; the CI lane skips them,
the local tier-1 command runs them.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    FedNL,
    FedNLBC,
    FedNLCR,
    FedNLLS,
    FedNLPP,
    RandK,
    RankR,
    TopK,
    Zero,
)
from repro.core.newton import fixed_hessian_run, newton_run
from repro.core.objectives import (
    batch_grad,
    batch_hess,
    global_value,
    lipschitz_constants,
)
from repro.data.synthetic import make_synthetic

pytestmark = pytest.mark.slow


@pytest.fixture(scope="module")
def problem():
    with enable_x64():
        data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                              n=8, m=60, d=16, lam=1e-3)
        data = data._replace(a=data.a.astype(jnp.float64),
                             b=data.b.astype(jnp.float64))
        grad_fn = lambda x: batch_grad(x, data)
        hess_fn = lambda x: batch_hess(x, data)
        val_fn = lambda x: global_value(x, data)
        xstar, _ = newton_run(jnp.zeros(16, jnp.float64), grad_fn, hess_fn, 50)
        yield dict(data=data, grad=grad_fn, hess=hess_fn, val=val_fn,
                   xstar=xstar, consts=lipschitz_constants(data))


def _x0_near(problem, scale=1e-2, seed=3):
    return problem["xstar"] + scale * jax.random.normal(
        jax.random.PRNGKey(seed), problem["xstar"].shape, jnp.float64)


def test_fednl_linear_rate_eq6(problem):
    """(6): ||x^k - x*||^2 <= (1/2^k) ||x^0 - x*||^2 locally."""
    with enable_x64():
        x0 = _x0_near(problem)
        alg = FedNL(problem["grad"], problem["hess"], RankR(1), alpha=1.0,
                    option=1, mu=1e-3)
        _, xs = alg.run(x0, 8, 18)
        r = jnp.sum((xs - problem["xstar"]) ** 2, axis=-1)
        for k in range(1, 15):
            assert float(r[k]) <= float(r[0]) / 2**k * 4 + 1e-24, k


def test_fednl_superlinear_ratio_decreases(problem):
    """(8): r_{k+1}/r_k -> 0."""
    with enable_x64():
        x0 = _x0_near(problem, scale=5e-2)
        alg = FedNL(problem["grad"], problem["hess"], RankR(2), alpha=1.0,
                    option=1, mu=1e-3)
        _, xs = alg.run(x0, 8, 14)
        r = jnp.sum((xs - problem["xstar"]) ** 2, axis=-1)
        ratios = [float(r[k + 1] / r[k]) for k in range(10) if r[k] > 1e-28]
        assert ratios[-1] < 0.2 * ratios[0] + 1e-12


def test_fednl_hessian_learning(problem):
    """Phi^k linear decay (7): H_i^k -> hess_i(x*)."""
    with enable_x64():
        x0 = _x0_near(problem)
        alg = FedNL(problem["grad"], problem["hess"], TopK(k=64), alpha=1.0,
                    option=2)
        state = alg.init(x0, 8)
        hstar = problem["hess"](problem["xstar"])
        h_err = [float(jnp.mean(jnp.sum((state.h_local - hstar) ** 2, (-2, -1))))]
        step = jax.jit(alg.step)
        for _ in range(25):
            state = step(state)
            h_err.append(float(jnp.mean(jnp.sum((state.h_local - hstar) ** 2,
                                                (-2, -1)))))
        assert h_err[-1] < 1e-3 * h_err[0]


def test_fednl_option2_converges(problem):
    with enable_x64():
        x0 = _x0_near(problem)
        alg = FedNL(problem["grad"], problem["hess"], RankR(1), alpha=1.0,
                    option=2)
        final, xs = alg.run(x0, 8, 25)
        gap = float(problem["val"](final.x) - problem["val"](problem["xstar"]))
        assert gap < 1e-16


def test_fednl_unbiased_randk(problem):
    with enable_x64():
        x0 = _x0_near(problem)
        comp = RandK(k=64)
        omega = comp.spec((16, 16)).omega
        alg = FedNL(problem["grad"], problem["hess"], comp,
                    alpha=1.0 / (1.0 + omega), option=1, mu=1e-3)
        final, _ = alg.run(x0, 8, 60)
        gap = float(problem["val"](final.x) - problem["val"](problem["xstar"]))
        assert gap < 1e-14


def test_n0_linear_ns_quadratic(problem):
    with enable_x64():
        x0 = _x0_near(problem, scale=5e-2)
        grad_fn = problem["grad"]
        h0 = jnp.mean(problem["hess"](x0), axis=0)
        _, xs = fixed_hessian_run(x0, h0, grad_fn, 15)
        r = jnp.linalg.norm(xs - problem["xstar"], axis=-1) ** 2
        assert float(r[10]) <= float(r[0]) / 2**10 * 16  # N0: 1/2^k up to slack

        hstar = jnp.mean(problem["hess"](problem["xstar"]), axis=0)
        _, xs = fixed_hessian_run(x0, hstar, grad_fn, 6)
        rr = jnp.linalg.norm(xs - problem["xstar"], axis=-1)
        # NS quadratic: r_{k+1} <= C r_k^2
        c = problem["consts"]["L_star"] / (2 * 1e-3)
        for k in range(3):
            if rr[k] > 1e-14:
                assert float(rr[k + 1]) <= c * float(rr[k]) ** 2 * 10


def test_fednl_pp_converges(problem):
    with enable_x64():
        x0 = _x0_near(problem)
        alg = FedNLPP(problem["grad"], problem["hess"], RankR(1), tau=3)
        final, _ = alg.run(x0, 8, 60)
        gap = float(problem["val"](final.x) - problem["val"](problem["xstar"]))
        assert gap < 1e-14


def test_fednl_ls_global(problem):
    with enable_x64():
        x_far = jnp.full((16,), 3.0, jnp.float64)
        alg = FedNLLS(problem["val"], problem["grad"], problem["hess"],
                      RankR(1), mu=1e-3)
        final, xs = alg.run(x_far, 8, 40)
        vals = [float(problem["val"](x)) for x in xs]
        assert all(vals[i + 1] <= vals[i] + 1e-12 for i in range(len(vals) - 1)), \
            "line search must be monotone"
        assert vals[-1] - float(problem["val"](problem["xstar"])) < 1e-12


def test_fednl_cr_global(problem):
    with enable_x64():
        x_far = jnp.full((16,), 2.0, jnp.float64)
        alg = FedNLCR(problem["grad"], problem["hess"], RankR(1),
                      l_star=problem["consts"]["L_star"])
        final, xs = alg.run(x_far, 8, 150)
        vals = [float(problem["val"](x)) for x in xs]
        fstar = float(problem["val"](problem["xstar"]))
        assert all(vals[i + 1] <= vals[i] + 1e-10 for i in range(len(vals) - 1)), \
            "cubic model step must decrease f"
        assert vals[-1] - fstar < 0.5 * (vals[0] - fstar)


def test_fednl_bc_converges(problem):
    with enable_x64():
        x0 = _x0_near(problem)
        d = 16
        alg = FedNLBC(problem["grad"], problem["hess"],
                      TopK(k=int(0.9 * d * d)), TopK(k=d), p=0.9,
                      option=1, mu=1e-3)
        final, zs = alg.run(x0, 8, 80)
        gap = float(problem["val"](final.z) - problem["val"](problem["xstar"]))
        assert gap < 1e-12


def test_newton_triangle_specializations(problem):
    """FedNL with C=0, alpha=0, H_i^0 = hess_i(x0) IS Newton-Zero."""
    with enable_x64():
        x0 = _x0_near(problem)
        alg = FedNL(problem["grad"], problem["hess"], Zero(), alpha=0.0,
                    option=1, mu=1e-3)
        _, xs_fednl = alg.run(x0, 8, 8)
        h0 = jnp.mean(problem["hess"](x0), axis=0)
        _, xs_n0 = fixed_hessian_run(x0, h0, problem["grad"], 8, mu=1e-3)
        np.testing.assert_allclose(np.asarray(xs_fednl),
                                   np.asarray(xs_n0), atol=1e-10)
