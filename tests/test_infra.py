"""Data pipeline, checkpointing, optimizers, FedNL preconditioner, and the
shard_map federated runtime."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save
from repro.core import FedNL, RankR
from repro.core.federated import run_fednl_sharded
from repro.core.objectives import batch_grad, batch_hess
from repro.data.libsvm import parse_libsvm, partition_across_silos
from repro.data.synthetic import make_iid, make_libsvm_like, make_synthetic
from repro.data.tokens import TokenPipeline
from repro.second_order import adamw, fednl_precond, sgd
from repro.second_order.fednl_precond import FedNLPrecondOptimizer, FedNLPrecondState
from repro.second_order.optim import apply_updates


# -- data ---------------------------------------------------------------------


def test_synthetic_shapes_and_labels():
    data = make_synthetic(jax.random.PRNGKey(0), 1.0, 1.0, n=5, m=11, d=7)
    assert data.a.shape == (5, 11, 7) and data.b.shape == (5, 11)
    assert set(np.unique(np.asarray(data.b))) <= {-1.0, 1.0}


def test_heterogeneity_increases_spread():
    """Synthetic(alpha, beta) with larger alpha/beta => more diverse silo
    optima (the knob Fig. 14 turns)."""

    def spread(alpha, beta):
        data = make_synthetic(jax.random.PRNGKey(1), alpha, beta, n=6, m=40,
                              d=10)
        hess = batch_hess(jnp.zeros(10), data)
        hbar = jnp.mean(hess, axis=0)
        return float(jnp.mean(jnp.sum((hess - hbar) ** 2, (-2, -1))))

    assert spread(10.0, 10.0) > spread(0.0, 0.0)


def test_libsvm_parser_roundtrip():
    text = "+1 1:0.5 3:1.0\n-1 2:2.0\n+1 1:1.0 2:1.0 3:1.0\n-1 3:0.25\n"
    a, b = parse_libsvm(text, d=3)
    np.testing.assert_allclose(a[0], [0.5, 0.0, 1.0])
    np.testing.assert_allclose(b, [1, -1, 1, -1])
    data = partition_across_silos(a, b, n=2)
    assert data.a.shape == (2, 2, 3)


def test_libsvm_like_shapes_match_table3():
    data = make_libsvm_like(jax.random.PRNGKey(0), "a1a")
    assert data.a.shape == (16, 100, 123)


def test_token_pipeline_deterministic_and_sharded_shape():
    pipe = TokenPipeline(vocab_size=100, seq_len=32, global_batch=8, seed=1)
    b1, b2 = pipe.batch(3), pipe.batch(3)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    assert b1["tokens"].shape == (8, 32)
    assert int(b1["tokens"].max()) < 100
    # targets are next-token shifted
    np.testing.assert_array_equal(np.asarray(b1["targets"][:, :-1]),
                                  np.asarray(b1["tokens"][:, 1:]))


# -- checkpoint ----------------------------------------------------------------


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3).astype(jnp.bfloat16),
            "b": [jnp.ones(4), {"c": jnp.zeros((2, 2))}]}
    save(str(tmp_path / "ck"), tree, step=7)
    restored, step = restore(str(tmp_path / "ck"), tree)
    assert step == 7
    for x, y in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32))
        assert x.dtype == y.dtype


# -- optimizers -----------------------------------------------------------------


def _quad_loss(params):
    return sum(jnp.sum((p - 3.0) ** 2) for p in jax.tree.leaves(params))


@pytest.mark.parametrize("make_opt", [
    lambda: sgd(0.1, momentum=0.9),
    lambda: adamw(0.05, weight_decay=0.0),
    lambda: fednl_precond(0.5, k_per_block=16, block=8),
])
def test_optimizers_minimize_quadratic(make_opt):
    opt = make_opt()
    params = {"w": jnp.zeros((4, 4)), "b": jnp.zeros(3)}
    state = opt.init(params)
    for _ in range(120):
        grads = jax.grad(_quad_loss)(params)
        upd, state = opt.update(grads, state, params)
        params = apply_updates(params, upd)
    assert _quad_loss(params) < 1e-2 * _quad_loss({"w": jnp.zeros((4, 4)),
                                                   "b": jnp.zeros(3)})


def test_fednl_precond_learns_curvature():
    """On a fixed quadratic the learned diagonal H tracks the (constant)
    Fisher-style observation via the compressed rule."""
    opt = FedNLPrecondOptimizer(lr=0.1, alpha=1.0, k_per_block=64, block=8)
    params = {"w": jnp.ones((8, 8))}
    state = opt.init(params)
    grads = {"w": jnp.full((8, 8), 2.0)}
    for _ in range(5):
        _, state = opt.update(grads, state, params)
    # observation D = g^2 = 4; k_per_block=64 = whole block => exact learn
    np.testing.assert_allclose(np.asarray(state.h["w"]), 4.0, atol=1e-5)


def test_fednl_precond_hutchinson_without_probe_raises():
    """Regression: curvature='hutchinson' with no hvp probe used to
    silently fall back to the Fisher diagonal — it must refuse, naming
    the missing probe."""
    opt = FedNLPrecondOptimizer(curvature="hutchinson")
    grads = {"w": jnp.ones((4, 4))}
    with pytest.raises(ValueError, match="hvp"):
        opt.observe(grads)
    with pytest.raises(ValueError, match="hutchinson"):
        opt.update(grads, opt.init(grads), grads)  # observe() inside
    # with the probe supplied, D = z * (H z)
    z = {"w": jnp.full((4, 4), 2.0)}
    hz = {"w": jnp.full((4, 4), 3.0)}
    obs = opt.observe(grads, hvp=(z, hz))
    np.testing.assert_allclose(np.asarray(obs["w"]), 6.0)


def test_fednl_precond_update_rule_matches_docstring():
    """Numeric pin of the documented Option-2 step
        l = ||D - H||_F / sqrt(numel)
        u = -lr * g / (sqrt(max(H, 0)) + sqrt(l) + eps)
    — the sqrt (Adam-consistent) denominator, including the max(H, 0)
    clamp on a negative curvature entry. momentum=0 and alpha=0 isolate
    the raw preconditioned step."""
    lr, eps = 0.2, 1e-8
    opt = FedNLPrecondOptimizer(lr=lr, alpha=0.0, momentum=0.0,
                                k_per_block=64, block=8, eps=eps)
    h0 = jnp.array([[4.0, 9.0], [-2.0, 0.0]])
    g = jnp.array([[1.0, -2.0], [3.0, 4.0]])
    params = {"w": jnp.zeros((2, 2))}
    state = FedNLPrecondState(jnp.zeros((), jnp.int32), {"w": h0},
                              {"w": jnp.zeros((2, 2))})
    obs = {"w": jnp.full((2, 2), 5.0)}
    upd, _ = opt.update({"w": g}, state, params, observations=obs)
    l = np.linalg.norm(np.asarray(obs["w"] - h0)) / 2.0  # /sqrt(numel=4)
    want = -lr * np.asarray(g) / (np.sqrt(np.maximum(np.asarray(h0), 0.0))
                                  + np.sqrt(l) + eps)
    np.testing.assert_allclose(np.asarray(upd["w"]), want, rtol=1e-5)


def test_fednl_precond_refresh_precondition_consistent_with_update():
    """The amortized protocol pin: ``refresh`` learns exactly the H (and
    ridge l) that ``update(..., observations=...)`` stores, while
    touching nothing else — step and mu come back bit-identical — and
    ``precondition`` on quiet steps reproduces ``update``'s no-obs step
    from that stored state. This is the contract ``make_train_step``'s
    lax.cond refresh gate relies on."""
    opt = FedNLPrecondOptimizer(lr=0.1, alpha=0.5, momentum=0.9,
                                k_per_block=16, block=8)
    params = {"w": jnp.zeros((8, 8)), "b": jnp.zeros(5)}
    grads = {"w": jnp.ones((8, 8)), "b": jnp.full(5, 2.0)}
    obs = opt.observe(grads)

    # the monolithic path: one update that both learns and steps
    s0 = opt.init(params)
    _, s_upd = opt.update(grads, s0, params, observations=obs)

    # the amortized path: refresh (learn only), then precondition (step)
    s_ref = opt.refresh(s0, obs)
    for leaf_u, leaf_r in zip(jax.tree.leaves(s_upd.h),
                              jax.tree.leaves(s_ref.h)):
        np.testing.assert_allclose(np.asarray(leaf_u), np.asarray(leaf_r))
    for leaf_u, leaf_r in zip(jax.tree.leaves(s_upd.l),
                              jax.tree.leaves(s_ref.l)):
        np.testing.assert_allclose(np.asarray(leaf_u), np.asarray(leaf_r))
    # refresh is learning-only: step and momentum are untouched
    assert int(s_ref.step) == int(s0.step)
    for leaf_0, leaf_r in zip(jax.tree.leaves(s0.mu),
                              jax.tree.leaves(s_ref.mu)):
        np.testing.assert_array_equal(np.asarray(leaf_0), np.asarray(leaf_r))

    # update's own step is precondition on the PRE-learning h with the
    # CURRENT observation's l (the documented legacy blend)
    upd_a, _ = opt.update(grads, s0, params, observations=obs)
    upd_b, s_b = opt.precondition(grads, s0._replace(l=s_upd.l), params)
    for leaf_a, leaf_b in zip(jax.tree.leaves(upd_a),
                              jax.tree.leaves(upd_b)):
        np.testing.assert_allclose(np.asarray(leaf_a), np.asarray(leaf_b))
    assert int(s_upd.step) == int(s_b.step) == 1


def test_fednl_precond_pallas_path_builds_no_dense_selection_mask():
    """Acceptance: with the Pallas payload ops forced (the TPU path,
    trace-only so it runs anywhere), the jaxpr of ``update`` contains
    no intermediate with a block^2 = 16384 trailing dim outside
    pallas_call bodies — neither the dense selection mask nor the dense
    per-tile scatter round-trip exists in the training step. The jaxpr
    walk lives in ``repro.analysis`` (the ``no-dense-roundtrip`` rule —
    the registry sweep applies it to every precond/kernel target); this
    test keeps the original call sites pinned plus the codec-compress
    positive control proving the detector sees such masks."""
    from repro import analysis

    d, block = 256, 128
    opt = FedNLPrecondOptimizer(lr=0.1, k_per_block=32, block=block,
                                use_pallas=True)
    params = {"w": jnp.zeros((d, d))}
    state = opt.init(params)
    grads = {"w": jnp.ones((d, d))}

    analysis.check(lambda g, s: opt.update(g, s, params), grads, state,
                   rules=["no-dense-roundtrip"], context={"block": block})

    obs = {"w": jnp.ones((3, d, d))}
    analysis.check(lambda g, s, o: opt.update(g, s, params, observations=o),
                   grads, state, obs,
                   rules=["no-dense-roundtrip"], context={"block": block})

    # positive control: the jnp codec DOES build (nblocks, block^2)
    comp = opt.compressor
    violations = analysis.check(
        lambda m: comp.decompress(comp.compress(m), m.shape), grads["w"],
        rules=["no-dense-roundtrip"], context={"block": block},
        raise_on_violation=False)
    assert violations
    assert {v.rule for v in violations} == {"no-dense-roundtrip"}


# -- shard_map federated runtime -------------------------------------------------


def test_fednl_sharded_matches_vmap_single_device():
    data = make_iid(jax.random.PRNGKey(0), n=4, m=30, d=10)
    grad_fn = lambda x: batch_grad(x, data)
    hess_fn = lambda x: batch_hess(x, data)
    x0 = jnp.ones(10) * 0.3

    alg_plain = FedNL(grad_fn, hess_fn, RankR(1), option=2)
    _, xs_plain = alg_plain.run(x0, 4, 6)

    mesh = jax.make_mesh((1,), ("data",))
    _, xs_sh = run_fednl_sharded(data, RankR(1), mesh, x0, 6, option=2)
    np.testing.assert_allclose(np.asarray(xs_plain), np.asarray(xs_sh),
                               atol=2e-4)  # reduction-order noise in f32


def test_fednl_sharded_multidevice_subprocess():
    """Real 4-way sharding equivalence, in a subprocess so the forced
    device count doesn't leak into this test session."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp, numpy as np
        from repro.core import FedNL, RankR
        from repro.core.federated import run_fednl_sharded
        from repro.core.objectives import batch_grad, batch_hess
        from repro.data.synthetic import make_synthetic

        data = make_synthetic(jax.random.PRNGKey(0), 0.5, 0.5, n=8, m=30, d=10)
        grad_fn = lambda x: batch_grad(x, data)
        hess_fn = lambda x: batch_hess(x, data)
        x0 = jnp.ones(10) * 0.3
        alg = FedNL(grad_fn, hess_fn, RankR(1), option=2)
        _, xs_plain = alg.run(x0, 8, 6)
        mesh = jax.make_mesh((4,), ("data",))
        _, xs_sh = run_fednl_sharded(data, RankR(1), mesh, x0, 6, option=2)
        np.testing.assert_allclose(np.asarray(xs_plain), np.asarray(xs_sh),
                                   atol=1e-4)
        print("SHARDED_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr
