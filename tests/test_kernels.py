"""Per-kernel allclose sweeps: shapes x dtypes vs the pure-jnp oracles,
executed with interpret=True (the kernel body itself runs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.block_topk import (
    block_topk,
    block_topk_payload,
    block_topk_payload_ref,
    block_topk_ref,
    payload_to_dense,
)
from repro.kernels.flash_attention import flash_attention, flash_attention_ref
from repro.kernels.hess_update import hess_update, hess_update_ref
from repro.kernels.scatter_accum import (
    block_scatter_accumulate,
    block_scatter_accumulate_ref,
    scatter_accumulate,
    scatter_accumulate_ref,
)
from repro.kernels.tiled_matmul import (
    powersgd_rank_r,
    powersgd_rank_r_ref,
    tiled_matmul,
    tiled_matmul_ref,
)

SHAPES_2D = [(128, 128), (256, 128), (300, 123), (64, 200), (17, 31)]
DTYPES = [jnp.float32, jnp.bfloat16]


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("k", [1, 16, 1000])
def test_block_topk_matches_ref_f32(shape, k):
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    out = block_topk(x, k=k, block=128)
    m, n = shape
    pm, pn = (-m) % 128, (-n) % 128
    xp = jnp.pad(x, ((0, pm), (0, pn)))
    ref = block_topk_ref(xp, k=k, block=128)[:m, :n]
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=1e-6)


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("k", [16, 1000])
def test_block_topk_bf16_semantics(shape, k):
    """bf16 quantization produces magnitude TIES, so threshold selection
    may keep a few more entries than the sort-based oracle; check the
    operator semantics instead of entrywise equality: kept entries are a
    superset-by-magnitude selection, count >= min(k, numel), and the
    contraction property holds."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape).astype(jnp.bfloat16)
    out = block_topk(x, k=k, block=128)
    xo = np.asarray(out, np.float32)
    xi = np.asarray(x, np.float32)
    kept = xo != 0
    # kept entries equal the input there
    np.testing.assert_allclose(xo[kept], xi[kept])
    # magnitude selection: every kept entry >= every dropped entry within
    # the single 128-block (shapes here are <= 128x... per block) up to ties
    assert kept.sum() >= min(k, (np.abs(xi) > 0).sum()) * 0.99
    # contraction with delta = k/block^2 per tile
    nm2 = float((xi ** 2).sum())
    assert float(((xo - xi) ** 2).sum()) <= nm2 + 1e-3


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("k", [1, 16, 200])
def test_block_topk_payload_matches_ref(shape, k):
    """The payload-emitting kernel agrees with the jnp payload oracle
    entrywise (values AND indices, flat in-tile order) and reconstructs
    the dense kernel's output exactly."""
    x = jax.random.normal(jax.random.PRNGKey(0), shape)
    vals, idx = block_topk_payload(x, k=k, block=128, use_pallas=True,
                                   interpret=True)
    m, n = shape
    pm, pn = (-m) % 128, (-n) % 128
    xp = jnp.pad(x, ((0, pm), (0, pn)))
    rv, ri = block_topk_payload_ref(xp, k=k, block=128)
    np.testing.assert_array_equal(np.asarray(idx), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(vals), np.asarray(rv))
    dense = payload_to_dense(vals, idx, shape, block=128)
    ref_dense = block_topk(x, k=k, block=128)
    np.testing.assert_array_equal(np.asarray(dense), np.asarray(ref_dense))


def test_block_topk_payload_vmap_over_silos():
    """Acceptance: the Pallas payload op agrees with the jnp reference
    under vmap over the silo axis (stacked Hessian diffs), with static
    payload shapes."""
    stack = jax.random.normal(jax.random.PRNGKey(2), (3, 256, 130))
    pad = jnp.pad(stack, ((0, 0), (0, 0), (0, (-130) % 128)))
    vv, ii = jax.vmap(lambda m: block_topk_payload(
        m, k=32, block=128, use_pallas=True, interpret=True))(stack)
    rv, ri = jax.vmap(
        lambda m: block_topk_payload_ref(m, k=32, block=128))(pad)
    assert vv.shape == (3, 2 * 2, 32) and ii.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(ii), np.asarray(ri))
    np.testing.assert_array_equal(np.asarray(vv), np.asarray(rv))


def test_block_topk_payload_tie_cluster_keeps_exactly_k():
    """Regression: a tie cluster spanning the k-th position must not
    undershoot (threshold-only cut) nor corrupt the reconstruction
    through -1 padding; the kernel's two-phase fill keeps exactly k."""
    t = jnp.zeros((128, 128)).at[:4, :4].set(
        jnp.full((4, 4), 1.0).at[0, 0].set(1.0001))
    vals, idx = block_topk_payload(t, k=3, block=128, use_pallas=True,
                                   interpret=True)
    dense = payload_to_dense(vals, idx, (128, 128), block=128)
    kept = np.asarray(dense) != 0
    assert kept.sum() == 3
    assert float(dense[0, 0]) == float(np.float32(1.0001))
    err = float(jnp.sum((dense - t) ** 2))
    nm2 = float(jnp.sum(t * t))
    assert err <= (1 - 3 / (128 * 128)) * nm2 * (1 + 1e-6)


def test_block_topk_payload_matches_compressor_payload():
    """The kernel's native output format IS BlockSparsePayload: same
    decompressed matrix as the core BlockTopK codec (selection sets
    agree on tie-free data; entry order differs, scatter doesn't care)."""
    from repro.core.compressors import BlockTopK

    x = jax.random.normal(jax.random.PRNGKey(3), (256, 256))
    comp = BlockTopK(k_per_block=64, block=128)
    vals, idx = block_topk_payload(x, k=64, block=128, use_pallas=True,
                                   interpret=True)
    via_kernel = payload_to_dense(vals, idx, x.shape, block=128)
    via_codec = comp.decompress(comp.compress(x), x.shape)
    np.testing.assert_array_equal(np.asarray(via_kernel),
                                  np.asarray(via_codec))


def test_block_topk_payload_dispatch_oracle_matches_kernel():
    """The off-TPU dispatch path (use_pallas=False -> sort-based jnp
    oracle) emits the same payload as the forced Pallas kernel body on
    tie-free data — the two backends of the one payload op agree."""
    x = jax.random.normal(jax.random.PRNGKey(7), (300, 123))
    kv, ki = block_topk_payload(x, k=48, block=128, use_pallas=True,
                                interpret=True)
    ov, oi = block_topk_payload(x, k=48, block=128, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(ov))


# None -> single-block kernel; (8, 128) -> forced tiled kernel (multi-
# tile grids even on the small test shapes)
SCATTER_PATHS = [None, (8, 128)]


@pytest.mark.parametrize("tile", SCATTER_PATHS)
@pytest.mark.parametrize("shape", [(37, 41), (128, 128), (1, 300)])
@pytest.mark.parametrize("k", [7, 700])
def test_scatter_accum_matches_ref(shape, k, tile):
    """The Pallas scatter-accumulate kernels (one-hot-matmul scatter,
    chunked over silos x entries; single-block and output-tiled) agree
    with the XLA scatter-add oracle, including duplicate indices across
    silos and -1 payload padding."""
    n = 4
    d0, d1 = shape
    vals = jax.random.normal(jax.random.PRNGKey(0), (n, k))
    idx = jax.random.randint(jax.random.PRNGKey(1), (n, k), 0,
                             d0 * d1).astype(jnp.int32)
    idx = idx.at[:, -2:].set(-1)  # padding slots with nonzero values
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True, tile=tile)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("tile", SCATTER_PATHS)
def test_scatter_accum_accumulates_duplicates(tile):
    """Every silo addressing the same cell: the accumulator must sum all
    of them (the server S = sum_i S_i semantics), not keep the last."""
    vals = jnp.ones((5, 3))
    idx = jnp.zeros((5, 3), jnp.int32).at[:, 1].set(7).at[:, 2].set(-1)
    out = scatter_accumulate(vals, idx, (2, 4), use_pallas=True,
                             interpret=True, tile=tile)
    expect = np.zeros((2, 4))
    expect[0, 0] = 5.0
    expect[1, 3] = 5.0
    np.testing.assert_allclose(np.asarray(out), expect, rtol=0, atol=1e-6)


@pytest.mark.parametrize("tile", SCATTER_PATHS)
@pytest.mark.parametrize("shape", [(37, 41), (17, 200)])
def test_scatter_accum_k_not_chunk_multiple(shape, tile):
    """k that is neither a _CHUNK multiple nor below it (513, 700 with
    _CHUNK=512) forces the zero/-1 chunk padding on both kernels; the
    padded tail must contribute nothing."""
    n = 3
    d0, d1 = shape
    for k in (513, 700):
        vals = jax.random.normal(jax.random.PRNGKey(k), (n, k))
        idx = jax.random.randint(jax.random.PRNGKey(k + 1), (n, k), 0,
                                 d0 * d1).astype(jnp.int32)
        out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                                 interpret=True, tile=tile)
        ref = scatter_accumulate_ref(vals, idx, shape)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=0, atol=1e-5)


@pytest.mark.parametrize("tile", SCATTER_PATHS)
def test_scatter_accum_duplicates_across_silos_and_chunks(tile):
    """The same flat cell addressed by every silo AND from both sides of
    a chunk boundary (k=600 > _CHUNK=512 splits each silo's stream into
    two kernel programs) must accumulate every contribution."""
    n, k, shape = 3, 600, (37, 41)
    target = 5 * 41 + 7  # one fixed cell
    vals = jax.random.normal(jax.random.PRNGKey(0), (n, k))
    idx = jax.random.randint(jax.random.PRNGKey(1), (n, k), 0,
                             shape[0] * shape[1]).astype(jnp.int32)
    # first and last slot of every silo -> same cell (slot 599 lands in
    # the second chunk after padding to 1024)
    idx = idx.at[:, 0].set(target).at[:, -1].set(target)
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True, tile=tile)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)
    expect_cell = float(jnp.sum(jnp.where(idx == target, vals, 0.0)))
    assert abs(float(out[5, 7]) - expect_cell) < 1e-4


@pytest.mark.parametrize("tile", SCATTER_PATHS)
def test_scatter_accum_all_padding_silo(tile):
    """A silo whose payload is entirely -1 padding (an absent
    participant) contributes exactly zero even with nonzero values."""
    n, k, shape = 4, 20, (17, 31)
    vals = jax.random.normal(jax.random.PRNGKey(2), (n, k))
    idx = jax.random.randint(jax.random.PRNGKey(3), (n, k), 0,
                             shape[0] * shape[1]).astype(jnp.int32)
    idx = idx.at[1, :].set(-1)
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True, tile=tile)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)
    without = scatter_accumulate_ref(
        jnp.delete(vals, 1, axis=0), jnp.delete(idx, 1, axis=0), shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(without),
                               rtol=0, atol=1e-5)


def test_scatter_accum_auto_tiles_above_vmem_budget():
    """Dispatch: a padded accumulator above the VMEM budget (f32
    1600x1664 > 8 MiB) silently routes to the tiled kernel and still
    matches the oracle — the d ~ 1500 single-block ceiling is gone."""
    from repro.kernels.scatter_accum.ops import _VMEM_ACC_BUDGET_BYTES

    n, k, shape = 2, 64, (1600, 1664)
    assert shape[0] * shape[1] * 4 > _VMEM_ACC_BUDGET_BYTES
    vals = jax.random.normal(jax.random.PRNGKey(4), (n, k))
    idx = jax.random.randint(jax.random.PRNGKey(5), (n, k), 0,
                             shape[0] * shape[1]).astype(jnp.int32)
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


@pytest.mark.parametrize("grid", [(1, 1), (2, 3)])
@pytest.mark.parametrize("kb", [1, 11])
def test_block_scatter_accum_matches_ref(grid, kb):
    gm, gn = grid
    n, b = 4, 8
    nblk = gm * gn
    vals = jax.random.normal(jax.random.PRNGKey(2), (n, nblk, kb))
    idx = jax.random.randint(jax.random.PRNGKey(3), (n, nblk, kb), 0,
                             b * b).astype(jnp.int32)
    idx = idx.at[:, :, -1:].set(-1)
    out = block_scatter_accumulate(vals, idx, grid, b, use_pallas=True,
                                   interpret=True)
    ref = block_scatter_accumulate_ref(vals, idx, grid, b)
    assert out.shape == (gm * b, gn * b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=0, atol=1e-5)


def test_scatter_accum_backs_compressor_aggregate():
    """Cross-validation: the kernel path reproduces the TopK/BlockTopK
    aggregate (which routes through the same ops with backend dispatch)
    on real compressed payloads."""
    from repro.core.compressors import BlockTopK, TopK

    m = jax.random.normal(jax.random.PRNGKey(4), (5, 256, 256))
    tk = TopK(k=300)
    pay = jax.vmap(tk.compress)(m)
    via_kernel = scatter_accumulate(pay.values, pay.indices, (1, 256 * 256),
                                    use_pallas=True,
                                    interpret=True).reshape(256, 256) / 5
    np.testing.assert_allclose(np.asarray(via_kernel),
                               np.asarray(tk.aggregate(pay, (256, 256))),
                               rtol=0, atol=1e-5)

    bt = BlockTopK(k_per_block=16, block=128)
    payb = jax.vmap(lambda x: bt.compress(x))(m)
    via_kernel = block_scatter_accumulate(payb.values, payb.indices, (2, 2),
                                          128, use_pallas=True,
                                          interpret=True) / 5
    np.testing.assert_allclose(np.asarray(via_kernel),
                               np.asarray(bt.aggregate(payb, (256, 256))),
                               rtol=0, atol=1e-5)


def test_block_topk_is_contractive():
    x = jax.random.normal(jax.random.PRNGKey(1), (256, 256))
    out = block_topk(x, k=64, block=128)
    delta = 64 / (128 * 128)
    nm2 = float(jnp.sum(x * x))
    assert float(jnp.sum(out * out)) <= nm2 + 1e-4
    assert float(jnp.sum((out - x) ** 2)) <= (1 - delta) * nm2 * (1 + 1e-6)


@pytest.mark.parametrize("shape", SHAPES_2D)
@pytest.mark.parametrize("dtype", DTYPES)
def test_hess_update_matches_ref(shape, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    h = jax.random.normal(ks[0], shape).astype(dtype)
    d = jax.random.normal(ks[1], shape).astype(dtype)
    s = jax.random.normal(ks[2], shape).astype(dtype)
    out, l = hess_update(h, d, s, alpha=0.7)
    ref_out, ref_l = hess_update_ref(h, d, s, alpha=0.7)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref_out, np.float32), atol=tol,
                               rtol=tol)
    assert abs(float(l) - float(ref_l)) <= tol * max(1.0, float(ref_l))


@pytest.mark.parametrize("mnk", [(128, 128, 128), (256, 64, 128),
                                 (100, 90, 70), (33, 257, 129)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_tiled_matmul_matches_ref(mnk, dtype):
    m, n, k = mnk
    a = jax.random.normal(jax.random.PRNGKey(0), (m, k)).astype(dtype)
    b = jax.random.normal(jax.random.PRNGKey(1), (k, n)).astype(dtype)
    out = tiled_matmul(a, b)
    ref = tiled_matmul_ref(a, b)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol * k,
                               rtol=tol)


@pytest.mark.parametrize("shape", [(150, 170), (256, 128)])
@pytest.mark.parametrize("r", [1, 4])
def test_powersgd_matches_ref(shape, r):
    m = jax.random.normal(jax.random.PRNGKey(0), shape)
    out = powersgd_rank_r(m, r)
    ref = powersgd_rank_r_ref(m, r)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)


def test_powersgd_captures_low_rank():
    """On an exactly rank-r matrix the compressor is (near) exact."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    u = jax.random.normal(k1, (96, 3))
    v = jax.random.normal(k2, (3, 80))
    m = u @ v
    out = powersgd_rank_r(m, 3, iters=4)
    rel = float(jnp.linalg.norm(out - m) / jnp.linalg.norm(m))
    assert rel < 1e-3


@pytest.mark.parametrize("t", [128, 200, 384])
@pytest.mark.parametrize("dtype", DTYPES)
def test_flash_attention_matches_ref(t, dtype):
    b, h, hd = 2, 3, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, h, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, h, hd)).astype(dtype)
    out = flash_attention(q, k, v)
    fold = lambda x: x.transpose(0, 2, 1, 3).reshape(b * h, t, hd)
    ref = flash_attention_ref(fold(q), fold(k), fold(v)) \
        .reshape(b, h, t, hd).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol, rtol=tol)


def test_flash_attention_is_causal():
    b, t, h, hd = 1, 128, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, h, hd))
    v = jax.random.normal(ks[2], (b, t, h, hd))
    out1 = flash_attention(q, k, v)
    # perturbing the FUTURE must not change past outputs
    k2 = k.at[:, -1].add(10.0)
    v2 = v.at[:, -1].add(10.0)
    out2 = flash_attention(q, k2, v2)
    np.testing.assert_allclose(np.asarray(out1[:, :-1]),
                               np.asarray(out2[:, :-1]), atol=1e-5)


def test_flash_kernel_matches_model_attention_path():
    """Cross-validation: the Pallas flash kernel agrees with the model's
    XLA chunked-attention path on identical GQA inputs (n_rep folded)."""
    from repro.models.attention import _sdpa_chunked

    b, t, h, hd = 1, 320, 2, 64
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (b, t, h, hd))
    k = jax.random.normal(ks[1], (b, t, h, hd))
    v = jax.random.normal(ks[2], (b, t, h, hd))
    out_model = _sdpa_chunked(q, k, v, n_rep=1, window=None, chunk=128)
    out_flash = flash_attention(q, k, v)
    np.testing.assert_allclose(np.asarray(out_model), np.asarray(out_flash),
                               atol=3e-5, rtol=3e-5)


# -- hess_update edge tiles (regression: grid used to floor-divide) -----------


def test_hess_update_kernel_edge_tiles_not_dropped():
    """Direct kernel call on a shape smaller than one block in the
    column dim: the old ``grid = (m // block, n // block)`` produced an
    EMPTY grid for (300, 123) and silently dropped every edge tile; the
    kernel now pads to the block grid and crops."""
    from repro.kernels.hess_update.kernel import hess_update_kernel

    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    h = jax.random.normal(ks[0], (300, 123))
    d = jax.random.normal(ks[1], (300, 123))
    s = jax.random.normal(ks[2], (300, 123))
    out, err = hess_update_kernel(h, d, s, 0.7, block=128, interpret=True)
    ref_out, ref_l = hess_update_ref(h, d, s, alpha=0.7)
    assert out.shape == (300, 123)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref_out),
                               atol=1e-6, rtol=1e-6)
    # zero padding contributes exactly 0 to the error partials
    np.testing.assert_allclose(float(jnp.sqrt(jnp.sum(err))), float(ref_l),
                               rtol=1e-6)
    # the edge rows/cols are real data, not zeros
    assert float(jnp.abs(out[256:, :]).sum()) > 0
    assert float(jnp.abs(out[:, 120:]).sum()) > 0


# -- fused diff -> top-k -> payload -------------------------------------------


@pytest.mark.parametrize("use_pallas", [True, False])
def test_diff_topk_payload_fused_matches_unfused_f64(use_pallas):
    """Equivalence pin at f64: the fused kernel's payload equals the
    unfused ``block_topk_payload(a - b)`` on the same backend, and its
    sumsq equals ``sum((a - b)**2)``. Zero accuracy change is the
    acceptance bar for the fusion."""
    from jax.experimental import enable_x64

    from repro.kernels.block_topk import diff_topk_payload

    with enable_x64():
        ka, kb = jax.random.split(jax.random.PRNGKey(11))
        a = jax.random.normal(ka, (256, 256), jnp.float64)
        b = jax.random.normal(kb, (256, 256), jnp.float64)
        vals, idx, sq = diff_topk_payload(a, b, k=32, block=128,
                                          use_pallas=use_pallas,
                                          interpret=True)
        uv, ui = block_topk_payload(a - b, k=32, block=128,
                                    use_pallas=use_pallas, interpret=True)
        assert vals.dtype == jnp.float64
        np.testing.assert_array_equal(np.asarray(idx), np.asarray(ui))
        np.testing.assert_allclose(np.asarray(vals), np.asarray(uv),
                                   rtol=1e-15, atol=0)
        # the free norm: per-tile partials vs the dense reduction
        np.testing.assert_allclose(float(sq), float(jnp.sum((a - b) ** 2)),
                                   rtol=1e-12)


def test_diff_topk_payload_dispatch_oracle_matches_kernel():
    """The two backends of the fused op (Pallas body vs sort-based jnp
    oracle) agree on tie-free data: same dense reconstruction, same
    sumsq."""
    ka, kb = jax.random.split(jax.random.PRNGKey(12))
    a = jax.random.normal(ka, (300, 123))
    b = jax.random.normal(kb, (300, 123))
    from repro.kernels.block_topk import diff_topk_payload

    kv, ki, ksq = diff_topk_payload(a, b, k=48, block=128, use_pallas=True,
                                    interpret=True)
    ov, oi, osq = diff_topk_payload(a, b, k=48, block=128, use_pallas=False)
    np.testing.assert_array_equal(np.asarray(ki), np.asarray(oi))
    np.testing.assert_array_equal(np.asarray(kv), np.asarray(ov))
    np.testing.assert_allclose(float(ksq), float(osq), rtol=1e-6)
    # padding tiles contribute zero: sumsq is the UNPADDED diff norm
    np.testing.assert_allclose(float(ksq),
                               float(jnp.sum((a - b) ** 2)), rtol=1e-6)


def test_diff_topk_payload_mixed_dtype_promotes():
    """result_type promotion matches the semantics of ``a - b``."""
    from repro.kernels.block_topk import diff_topk_payload

    a = jax.random.normal(jax.random.PRNGKey(1), (128, 128), jnp.float32)
    b = jax.random.normal(jax.random.PRNGKey(2), (128, 128)).astype(
        jnp.bfloat16)
    vals, idx, sq = diff_topk_payload(a, b, k=8, block=128,
                                      use_pallas=False)
    assert vals.dtype == (a - b).dtype


# -- symmetric mirror fused into the scatter ----------------------------------


@pytest.mark.parametrize("tile", SCATTER_PATHS)
def test_scatter_accum_symmetric_fused_matches_two_pass_f64(tile):
    """The in-kernel mirror (every off-diagonal (r, c) also lands at
    (c, r)) equals the two-pass oracle ``c + c.T - diag(diag(c))`` at
    f64 — on both the single-block and tiled kernels, with -1 payload
    padding present."""
    from jax.experimental import enable_x64

    with enable_x64():
        d = 64
        ks = jax.random.split(jax.random.PRNGKey(13), 3)
        r = jax.random.randint(ks[0], (3, 40), 0, d)
        c = jax.random.randint(ks[1], (3, 40), 0, d)
        rows, cols = jnp.maximum(r, c), jnp.minimum(r, c)  # lower triangle
        idx = (rows * d + cols).astype(jnp.int32)
        idx = idx.at[:, -5:].set(-1)  # payload padding must stay inert
        vals = jax.random.normal(ks[2], (3, 40), jnp.float64)
        out = scatter_accumulate(vals, idx, (d, d), use_pallas=True,
                                 interpret=True, tile=tile, symmetric=True)
        base = scatter_accumulate_ref(vals, idx, (d, d))
        expect = base + base.T - jnp.diag(jnp.diag(base))
        np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                                   rtol=1e-12, atol=1e-12)
        # the jnp dispatch path agrees exactly
        ref = scatter_accumulate(vals, idx, (d, d), use_pallas=False,
                                 symmetric=True)
        np.testing.assert_array_equal(np.asarray(ref), np.asarray(expect))


def test_scatter_accum_symmetric_diagonal_not_doubled():
    """A payload of diagonal entries only: the fused mirror must leave
    the diagonal single-counted (mirror contribution masked at r==c)."""
    d = 16
    diag_idx = (jnp.arange(8) * d + jnp.arange(8)).astype(jnp.int32)
    vals = jnp.arange(1.0, 9.0)[None, :]
    out = scatter_accumulate(vals, diag_idx[None, :], (d, d),
                             use_pallas=True, interpret=True,
                             symmetric=True)
    plain = scatter_accumulate(vals, diag_idx[None, :], (d, d),
                               use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(plain))


# -- streamed silo-slab scatter-accumulate ------------------------------------


def _pair_stream(n, k, shape, seed=0, pad_rows=(), dtype=jnp.float32):
    d0, d1 = shape
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.normal(kv, (n, k), dtype=dtype)
    idx = jax.random.randint(ki, (n, k), 0, d0 * d1, dtype=jnp.int32)
    for r in pad_rows:
        idx = idx.at[r].set(-1)  # all-padding silo (e.g. dropped client)
    return vals, idx


@pytest.mark.parametrize("silo_chunk", [1, 2, 3, 7, None])
@pytest.mark.parametrize("symmetric", [False, True])
def test_streamed_matches_stacked_bitwise(silo_chunk, symmetric):
    """The streamed silo-slab path must be BITWISE equal to the stacked
    scatter on the portable path — including slabs that are entirely
    padding (silos 10 and 11 form one all-padding chunk at
    silo_chunk=2) and across every chunk-boundary alignment."""
    from repro.kernels.scatter_accum import streamed_scatter_accumulate

    shape = (24, 24)
    vals, idx = _pair_stream(13, 40, shape, pad_rows=(3, 10, 11, 12))
    stacked = scatter_accumulate(vals, idx, shape, use_pallas=False,
                                 symmetric=symmetric)
    streamed = streamed_scatter_accumulate(
        vals, idx, shape, silo_chunk=silo_chunk, use_pallas=False,
        symmetric=symmetric)
    np.testing.assert_array_equal(np.asarray(streamed),
                                  np.asarray(stacked))


@pytest.mark.parametrize("tile", [None, (8, 8)])
@pytest.mark.parametrize("silo_chunk", [2, 5])
def test_streamed_matches_stacked_forced_pallas(tile, silo_chunk):
    """Forced Pallas dispatch (interpret mode — the kernel bodies run):
    chaining silo slabs through the init-accumulator kernels replays
    the stacked kernel's add sequence exactly."""
    from repro.kernels.scatter_accum import streamed_scatter_accumulate

    shape = (16, 16)
    vals, idx = _pair_stream(7, 12, shape, pad_rows=(4,))
    stacked = scatter_accumulate(vals, idx, shape, use_pallas=True,
                                 interpret=True, tile=tile, chunk=8)
    streamed = streamed_scatter_accumulate(
        vals, idx, shape, silo_chunk=silo_chunk, use_pallas=True,
        interpret=True, tile=tile, chunk=8)
    np.testing.assert_array_equal(np.asarray(streamed),
                                  np.asarray(stacked))


def test_silo_chunk_for_respects_budget():
    """The streaming rule: the largest silo slab whose (value, index)
    pair stream still fits the shared kernel VMEM budget — never zero,
    even when one silo alone overflows the budget."""
    from repro.kernels import VMEM_BUDGET_BYTES
    from repro.kernels.scatter_accum import silo_chunk_for

    k = 1024
    pair = jnp.dtype(jnp.float64).itemsize + jnp.dtype(jnp.int32).itemsize
    chunk = silo_chunk_for(k, jnp.float64)
    assert chunk >= 1
    assert chunk * k * pair <= VMEM_BUDGET_BYTES
    assert (chunk + 1) * k * pair > VMEM_BUDGET_BYTES
    # a single monster silo still streams, one silo at a time
    assert silo_chunk_for(10 * VMEM_BUDGET_BYTES, jnp.float64) == 1
