"""PSD projection (A.4) and the cubic subproblem solver (E.2)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need the optional [test] extra")
from hypothesis import given, settings, strategies as st

from repro.core.linalg import frob_norm, project_psd, solve_cubic_subproblem, symmetrize


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(2, 12),
       mu=st.floats(0.0, 2.0))
def test_project_psd_properties(seed, d, mu):
    m = jax.random.normal(jax.random.PRNGKey(seed), (d, d))
    p = project_psd(m, mu)
    evals = np.linalg.eigvalsh(np.asarray(p))
    assert evals.min() >= mu - 1e-4
    np.testing.assert_allclose(p, p.T, atol=1e-5)


def test_project_psd_is_projection():
    # projecting an already-feasible matrix is (near) identity
    key = jax.random.PRNGKey(0)
    a = jax.random.normal(key, (6, 6))
    m = symmetrize(a @ a.T) + 0.5 * jnp.eye(6)
    np.testing.assert_allclose(project_psd(m, 0.1), m, atol=1e-4)


def test_project_psd_closest_point():
    # the projection minimizes Frobenius distance among feasible points
    key = jax.random.PRNGKey(1)
    m = symmetrize(jax.random.normal(key, (5, 5)))
    p = project_psd(m, 0.0)
    d0 = float(frob_norm(p - m))
    for seed in range(5):
        q = jax.random.normal(jax.random.PRNGKey(seed + 2), (5, 5))
        feas = symmetrize(q @ q.T)  # arbitrary PSD point
        assert float(frob_norm(feas - m)) >= d0 - 1e-5


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000), d=st.integers(2, 10),
       mcube=st.floats(0.1, 10.0))
def test_cubic_subproblem_stationarity(seed, d, mcube):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    g = jax.random.normal(k1, (d,))
    a = jax.random.normal(k2, (d, d))
    h_mat = symmetrize(a)  # possibly indefinite
    h = solve_cubic_subproblem(g, h_mat, mcube)
    # stationarity: g + (H + M/2 ||h|| I) h = 0  (bisection solver; the
    # Moré–Sorensen "hard case" is only approximated — see linalg.py)
    resid = g + h_mat @ h + 0.5 * mcube * jnp.linalg.norm(h) * h
    assert float(jnp.linalg.norm(resid)) <= 1e-2 * (1.0 + float(jnp.linalg.norm(g)))


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_cubic_subproblem_is_minimum(seed):
    d = 6
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    g = jax.random.normal(k1, (d,))
    h_mat = symmetrize(jax.random.normal(k2, (d, d)))
    m = 2.0

    def t_val(h):
        return float(g @ h + 0.5 * h @ h_mat @ h
                     + m / 6 * jnp.linalg.norm(h) ** 3)

    h_star = solve_cubic_subproblem(g, h_mat, m)
    v_star = t_val(h_star)
    for i in range(20):
        pert = 0.1 * jax.random.normal(jax.random.fold_in(k3, i), (d,))
        assert t_val(h_star + pert) >= v_star - 1e-4
