"""Per-architecture smoke tests (required): REDUCED variant of each family
(2 layers, d_model <= 512, <= 4 experts) — one forward + one train step on
CPU asserting output shapes and no NaNs; plus decode-vs-forward parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config
from repro.launch.steps import make_optimizer, make_serve_step, make_train_step
from repro.models import build_model

# ~1 min of compile-heavy smoke across 10 architectures: slow lane only
pytestmark = pytest.mark.slow


def _batch(cfg, b=2, t=32, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (b, t), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            key, (b, cfg.vision_tokens, cfg.d_model)) * 0.02
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    assert cfg.n_layers <= 2 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.num_experts <= 4
    model = build_model(cfg, use_remat=True)
    params = model.init_params(jax.random.PRNGKey(0))
    b, t = 2, 32
    batch = _batch(cfg, b, t)

    logits, aux = model.forward(params, batch)
    t_total = t + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (b, t_total, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())

    opt = make_optimizer("adamw", 1e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(model, opt))
    p2, s2, metrics = step(params, state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # one more step must not NaN and should usually reduce loss
    p3, s3, m3 = step(p2, s2, batch)
    assert np.isfinite(float(m3["loss"]))
    assert float(m3["loss"]) < float(metrics["loss"]) + 0.5


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, use_remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    b, cache_len = 2, 16
    cache = model.init_cache(b, cache_len)
    if cfg.family == "encdec":
        cache["enc"] = jnp.zeros((b, cfg.enc_seq, cfg.d_model))
    serve = jax.jit(make_serve_step(model))
    tok = jnp.ones((b, 1), jnp.int32)
    logits, cache2 = serve(params, cache, tok, jnp.asarray(3, jnp.int32))
    assert logits.shape == (b, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits).any())
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


NON_MOE = [a for a in ARCHS if get_config(a, smoke=True).moe is None
           and get_config(a).family != "encdec"]


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_fednl_five_steps_decreasing(arch):
    """5 real fednl steps through the LAUNCH DRIVER (sharded params +
    opt state, curvature refresh every 2 steps, preconditioned updates)
    on every arch in the zoo: finite, decreasing loss."""
    from repro.launch.train import train

    hist = train(arch, smoke=True, steps=5, batch=4, seq=32, lr=1e-3,
                 optimizer="fednl", log_every=10, refresh_every=2,
                 curvature_k=256)
    assert len(hist) == 5 and all(np.isfinite(h) for h in hist), hist
    assert hist[-1] < hist[0], hist


@pytest.mark.parametrize("arch", NON_MOE)
def test_decode_matches_forward(arch):
    """Teacher-forced forward logits == step-by-step decode logits.
    (MoE archs excluded: capacity-based dropping differs between the
    prefill group size and the single-token decode group — documented.)"""
    cfg = get_config(arch, smoke=True)
    model = build_model(cfg, use_remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    b, t = 2, 20
    toks = jax.random.randint(jax.random.PRNGKey(7), (b, t), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": toks}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((b, cfg.vision_tokens, cfg.d_model))
        pytest.skip("vlm decode starts after the patch prefix; covered by "
                    "smoke decode")
    logits_fwd, _ = model.forward(params, batch)
    cache = model.init_cache(b, t)
    serve = jax.jit(make_serve_step(model))
    for pos in range(t):
        lg, cache = serve(params, cache, toks[:, pos:pos + 1],
                          jnp.asarray(pos, jnp.int32))
        np.testing.assert_allclose(np.asarray(lg[:, 0]),
                                   np.asarray(logits_fwd[:, pos]),
                                   atol=2e-3, rtol=2e-3)


def test_sliding_window_masks_old_tokens():
    cfg = get_config("starcoder2-3b", smoke=True)  # window 16 in smoke
    assert cfg.sliding_window == 16
    model = build_model(cfg, use_remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    t = 40
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, t), 0, cfg.vocab)
    logits, _ = model.forward(params, {"tokens": toks, "targets": toks})
    # changing a token > window positions in the past must not affect logits
    toks2 = toks.at[0, 0].set((toks[0, 0] + 1) % cfg.vocab)
    logits2, _ = model.forward(params, {"tokens": toks2, "targets": toks2})
    np.testing.assert_allclose(np.asarray(logits[0, -1]),
                               np.asarray(logits2[0, -1]), atol=1e-4)


def test_moe_router_balance_loss_positive():
    cfg = get_config("granite-moe-1b-a400m", smoke=True)
    model = build_model(cfg, use_remat=False)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    _, aux = model.forward(params, batch)
    assert float(aux) >= 0.9  # >= 1 at perfect balance, ~E at collapse


def test_param_counts_match_analytic():
    """Analytic count (roofline MODEL_FLOPS) ~ actual init within 2%."""
    from repro.launch.roofline import count_params

    for arch in ["qwen2-0.5b", "granite-moe-1b-a400m", "xlstm-350m"]:
        cfg = get_config(arch, smoke=True)
        model = build_model(cfg)
        params = jax.eval_shape(model.init_params, jax.random.PRNGKey(0))
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
        est = count_params(cfg)
        assert abs(actual - est) / actual < 0.02, (arch, actual, est)
