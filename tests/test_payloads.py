"""Wire-format payload API tests (no optional deps): payload round-trips
are bit-identical to the seed-era dense operators, analytic bits are
clamped to what the payload can contain, measured bits (payload
structure via jax.eval_shape) match the analytic claims under x64, the
compressor registry constructs every family, and payload shapes stay
static under vmap over a silo axis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from _dense_refs import (
    blocktopk_dense_ref,
    randk_dense_ref,
    rankr_dense_ref,
    topk_dense_ref,
)
from repro.core.compressors import (
    FLOAT_BITS,
    INDEX_BITS,
    BlockTopK,
    RandK,
    RankR,
    TopK,
    Zero,
    available_compressors,
    make_compressor,
    payload_bits,
)

# -- bits clamps (regression: no overcount on small problems) ----------------


def test_topk_bits_clamped_to_numel():
    # a Top-K larger than the matrix ships the matrix, not more
    assert TopK(k=100).bits((3, 3)) == 9 * (FLOAT_BITS + INDEX_BITS)
    assert TopK(k=9).bits((3, 3)) == 9 * (FLOAT_BITS + INDEX_BITS)


def test_topk_symmetric_bits_count_lower_triangle_only():
    # symmetric Top-K keeps (and ships) only lower-triangular entries
    tri = 4 * 5 // 2
    assert TopK(k=100, symmetric=True).bits((4, 4)) == \
        tri * (FLOAT_BITS + INDEX_BITS)
    assert TopK(k=3, symmetric=True).bits((4, 4)) == \
        3 * (FLOAT_BITS + INDEX_BITS)


def test_randk_bits_clamped_to_numel():
    assert RandK(k=100).bits((3, 3)) == 9 * (FLOAT_BITS + INDEX_BITS)


def test_blocktopk_bits_clamped_to_block_size():
    # k_per_block larger than a tile ships the tile
    assert BlockTopK(k_per_block=100, block=4).bits((4, 4)) == \
        16 * (FLOAT_BITS + INDEX_BITS)


def test_bits_match_payload_shapes_after_clamp():
    # the analytic claim equals the measured payload structure under x64
    with enable_x64():
        for comp, shape in [(TopK(k=100), (3, 3)),
                            (TopK(k=100, symmetric=True), (4, 4)),
                            (RandK(k=100), (3, 3)),
                            (BlockTopK(k_per_block=100, block=4), (4, 4)),
                            (RankR(r=100), (5, 5)),
                            (Zero(), (5, 5))]:
            assert comp.bits(shape) == payload_bits(comp, shape), comp


# -- payload round-trips: bit-identical to the seed-era dense operators ------


def _rand(seed, d0, d1):
    return jax.random.normal(jax.random.PRNGKey(seed), (d0, d1))


@pytest.mark.parametrize("k", [1, 17, 144, 600])
def test_topk_roundtrip_bit_identical(k):
    m = _rand(0, 12, 12)
    comp = TopK(k=k)
    out = comp.decompress(comp.compress(m), m.shape)
    assert np.array_equal(np.asarray(out), np.asarray(topk_dense_ref(m, k)))


@pytest.mark.parametrize("k", [1, 17, 78, 600])
def test_topk_symmetric_roundtrip_bit_identical(k):
    m = _rand(1, 12, 12)
    comp = TopK(k=k, symmetric=True)
    out = comp.decompress(comp.compress(m), m.shape)
    ref = topk_dense_ref(m, k, symmetric=True)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("k", [1, 9, 63, 200])
def test_randk_roundtrip_bit_identical(k):
    m = _rand(2, 7, 9)
    key = jax.random.PRNGKey(42)
    comp = RandK(k=k)
    out = comp.decompress(comp.compress(m, key), m.shape)
    assert np.array_equal(np.asarray(out),
                          np.asarray(randk_dense_ref(m, k, key)))


@pytest.mark.parametrize("kb", [1, 5, 16, 30])
def test_blocktopk_roundtrip_bit_identical(kb):
    m = _rand(3, 10, 14)
    comp = BlockTopK(k_per_block=kb, block=4)
    out = comp.decompress(comp.compress(m), m.shape)
    ref = blocktopk_dense_ref(m, kb, 4)
    assert np.array_equal(np.asarray(out), np.asarray(ref))


@pytest.mark.parametrize("r", [1, 3, 12])
def test_rankr_roundtrip_bit_identical(r):
    m = _rand(4, 12, 12)
    m = 0.5 * (m + m.T)
    comp = RankR(r=r)
    out = comp.decompress(comp.compress(m), m.shape)
    assert np.array_equal(np.asarray(out), np.asarray(rankr_dense_ref(m, r)))


# -- threshold-variant tie handling (regressions) ----------------------------


def test_blocktopk_threshold_negative_padding_is_dropped():
    """jax normalizes negative indices before the mode='drop' bounds
    check, so -1 payload padding must be remapped before the scatter —
    regression: the padding pair (0, -1) used to zero the tile's last
    surviving entry."""
    from repro.core.compressors import BlockSparsePayload, BlockTopKThreshold

    comp = BlockTopKThreshold(k_per_block=3, block=2)
    pay = BlockSparsePayload(values=jnp.asarray([[5.0, 9.0, 0.0]]),
                             indices=jnp.asarray([[2, 3, -1]], jnp.int32))
    out = comp.decompress(pay, (2, 2))
    np.testing.assert_array_equal(np.asarray(out), [[0.0, 0.0], [5.0, 9.0]])


def test_blocktopk_threshold_tie_cluster_keeps_exactly_k():
    """A tie cluster spanning the k-th position must not undershoot: the
    two-phase selection (strict survivors, then boundary ties) keeps
    exactly k entries including the strictly-largest one, preserving
    the Def 3.3 contraction spec() reports."""
    from repro.core.compressors import BlockTopKThreshold

    t = jnp.full((4, 4), 1.0).at[0, 0].set(1.0001)
    comp = BlockTopKThreshold(k_per_block=3, block=4)
    out = comp(t)
    kept = np.asarray(out) != 0
    assert kept.sum() == 3
    assert float(out[0, 0]) == float(np.float32(1.0001))
    nm2 = float(jnp.sum(t * t))
    err = float(jnp.sum((out - t) ** 2))
    delta = comp.spec((4, 4)).delta
    assert err <= (1 - delta) * nm2 * (1 + 1e-6)


# -- registry-wide properties ------------------------------------------------

# every registered family with a usable level for the round-trip test
_FAMILY_LEVELS = {
    "rankr": 2, "rank": 2, "topk": 17, "topksym": 17, "powersgd": 2,
    "randk": 17, "blocktopk": 5, "blocktopkthreshold": 5,
    "natural": 0.4, "identity": None, "none": None, "zero": None,
    "dithering": 4, "randomdithering": 4,
}


def test_every_registered_family_has_level_params():
    missing = [f for f in available_compressors() if f not in _FAMILY_LEVELS]
    assert not missing, f"no round-trip level for families {missing}"


@pytest.mark.parametrize("family", sorted(_FAMILY_LEVELS))
def test_registry_roundtrip_call_equals_decompress_compress(family):
    """For every registered family: the registry constructs it, the dense
    __call__ equals decompress(compress(...)) exactly, and the payload
    keeps a static structure under vmap over a silo axis."""
    comp = make_compressor(family, _FAMILY_LEVELS[family])
    shape = (12,) if family in ("dithering", "randomdithering") else (12, 12)
    m = jax.random.normal(jax.random.PRNGKey(3), shape)
    key = jax.random.PRNGKey(4)
    out_call = comp(m, key)
    out_rt = comp.decompress(comp.compress(m, key), shape)
    assert np.array_equal(np.asarray(out_call), np.asarray(out_rt)), family

    # payload shapes static under vmap: leading silo axis only
    stack = jax.random.normal(jax.random.PRNGKey(5), (3,) + shape)
    keys = jax.random.split(key, 3)
    single = jax.eval_shape(comp.compress, m, key)
    batched = jax.eval_shape(
        lambda s, ks: jax.vmap(comp.compress)(s, ks), stack, keys)
    for one, bat in zip(jax.tree.leaves(single), jax.tree.leaves(batched)):
        assert bat.shape == (3,) + one.shape, family
        assert bat.dtype == one.dtype, family
    # per-silo measured bits are batching-invariant
    assert single.bits() == batched.bits(), family


def test_registry_unknown_family():
    with pytest.raises(ValueError, match="unknown compressor family"):
        make_compressor("not-a-compressor", 1)


@pytest.mark.parametrize("family", sorted(
    f for f in _FAMILY_LEVELS if f != "zero"))
def test_registry_def33_def32_inequalities(family):
    """Def 3.3 contraction for every deterministic family (PowerSGD at
    its guaranteed delta=0), Def 3.2 first inequality (unbiasedness to
    MC tolerance) for randomized ones."""
    comp = make_compressor(family, _FAMILY_LEVELS[family])
    shape = (12,) if family in ("dithering", "randomdithering") else (12, 12)
    sp = comp.spec(shape)
    m = jax.random.normal(jax.random.PRNGKey(7), shape)
    if family == "topksym":  # symmetric variant: domain is Hessian diffs
        m = 0.5 * (m + m.T)
    if sp.deterministic:
        delta = 0.0 if family == "powersgd" else sp.delta
        c = comp(m)
        nm = float(jnp.linalg.norm(m))
        err = float(jnp.linalg.norm(c - m)) ** 2
        assert float(jnp.linalg.norm(c)) <= nm * (1 + 1e-5), family
        assert err <= (1 - delta) * nm**2 + 1e-5 * nm**2, family
    else:
        keys = jax.random.split(jax.random.PRNGKey(8), 3000)
        mean = jnp.mean(jax.vmap(lambda k: comp(m, k))(keys), axis=0)
        np.testing.assert_allclose(mean, m, atol=0.3)
        assert sp.omega is not None and sp.omega >= 0
