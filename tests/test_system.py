"""End-to-end system tests: the train driver learns, the serve driver
generates, and the dry-run path lowers+compiles on a host-scale mesh."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest


@pytest.mark.slow
def test_train_driver_learns():
    from repro.launch.train import train

    hist = train("qwen2-0.5b", smoke=True, steps=30, batch=4, seq=64,
                 lr=1e-3, optimizer="adamw", log_every=100)
    assert hist[-1] < hist[0] - 0.5, hist[:3] + hist[-3:]


@pytest.mark.slow
def test_train_driver_fednl_optimizer_learns():
    from repro.launch.train import train

    hist = train("qwen2-0.5b", smoke=True, steps=30, batch=4, seq=64,
                 lr=2e-3, optimizer="fednl", log_every=100)
    assert hist[-1] < hist[0] - 0.5, hist[:3] + hist[-3:]


def test_train_microbatching_equivalence():
    """k-microbatch accumulation == full-batch step (same grads)."""
    from repro.configs import get_config
    from repro.launch.steps import make_optimizer, make_train_step
    from repro.models import build_model

    cfg = get_config("qwen2-0.5b", smoke=True)
    model = build_model(cfg, use_remat=True)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = make_optimizer("sgd", 1e-2)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0, cfg.vocab)
    batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}

    p1, _, m1 = jax.jit(make_train_step(model, opt, 1))(
        params, opt.init(params), batch)
    p2, _, m2 = jax.jit(make_train_step(model, opt, 2))(
        params, opt.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_serve_driver_generates():
    from repro.launch.serve import generate

    seqs = generate("xlstm-350m", smoke=True, batch=2, prompt_len=8, gen=6)
    assert seqs.shape == (2, 14)
    assert not bool(jnp.any(seqs < 0))


def test_dryrun_smoke_mesh_subprocess():
    """The dry-run path (shardings, lower, compile, cost/memory analysis)
    on an 8-device host mesh with the reduced config."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import jax
        from repro.launch.dryrun import dryrun_pair
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        row = dryrun_pair("qwen2-0.5b", "train_4k", mesh=mesh, smoke=True,
                          verbose=False, with_probes=False)
        assert row["status"] == "ok", row
        assert row["flops"] > 0 and row["peak_bytes_per_device"] > 0
        row2 = dryrun_pair("granite-moe-1b-a400m", "decode_32k", mesh=mesh,
                           smoke=True, verbose=False, with_probes=False)
        assert row2["status"] == "ok", row2
        print("DRYRUN_OK")
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert "DRYRUN_OK" in out.stdout, out.stdout[-2000:] + out.stderr[-3000:]


def test_collective_bytes_parser():
    from repro.launch.roofline import collective_bytes

    hlo = """
      %all-gather.1 = f32[16,64]{1,0} all-gather(%x), dimensions={0}
      %ar = (bf16[8,8]{1,0}, bf16[4]{0}) all-reduce(%a, %b)
      %rs.2 = f32[4,4]{1,0} reduce-scatter(%y), dimensions={0}
      %aa = bf16[2,2]{1,0} all-to-all(%z)
      %cp-start = f32[10]{0} collective-permute-start(%w)
      %cp-done = f32[10]{0} collective-permute-done(%cp-start)
      %notacoll = f32[100]{0} add(%p, %q)
    """
    out = collective_bytes(hlo)
    assert out["all-gather"] == 16 * 64 * 4
    assert out["all-reduce"] == 8 * 8 * 2 + 4 * 2
    assert out["reduce-scatter"] == 4 * 4 * 4
    assert out["all-to-all"] == 2 * 2 * 2
    assert out["collective-permute"] == 10 * 4  # start counted, done not


def test_skip_reasons_match_design():
    from repro.configs import get_config
    from repro.launch.shapes import SHAPES, skip_reason

    runs_500k = {a for a in
                 ["jamba-1.5-large-398b", "xlstm-350m", "starcoder2-15b",
                  "starcoder2-3b"]}
    from repro.configs import ARCHS

    for arch in ARCHS:
        cfg = get_config(arch)
        r = skip_reason(cfg, SHAPES["long_500k"])
        assert (r is None) == (arch in runs_500k), (arch, r)
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert skip_reason(cfg, SHAPES[s]) is None
