"""The second-order train step end to end: curvature observations must
flow through a JITTED ``make_train_step`` (the PR-4 adapter fix only
covered the optimizer protocol — the step itself used to call
``optimizer.update`` with 3 args, so the silo-axis channel was dead),
the refresh interval must gate the expensive phase, microbatch
accumulation must match the monolithic batch, and optimizer state must
carry the params' shardings."""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_optimizer, make_train_step
from repro.models import build_model


def _tiny(arch="qwen2-0.5b"):
    cfg = get_config(arch).reduced(n_layers=1, d_model=64, d_ff=128,
                                   vocab=128)
    model = build_model(cfg, use_remat=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _batch(cfg, b=4, t=32, seed=0):
    toks = jax.random.randint(jax.random.PRNGKey(seed), (b, t), 0, cfg.vocab)
    return {"tokens": toks, "targets": jnp.roll(toks, -1, axis=1)}


def test_fednl_observations_flow_through_jitted_train_step():
    """Regression for the dead observation path: with exact compression
    (k = block^2) and alpha=1 from H=0, one jitted train step must leave
    H == mean over silos of the per-silo squared grads — i.e. the
    silo-stacked observations really reached ``optimizer.refresh``
    through the cross-silo payload path, not a global-grad fallback."""
    cfg, model, params = _tiny()
    opt = make_optimizer("fednl", 1e-2, k_per_block=64, block=8)
    batch = _batch(cfg, b=4)
    step = jax.jit(make_train_step(model, opt, refresh_every=1, n_silos=2))

    state = opt.init(params)
    _, state, metrics = step(params, state, batch)
    assert float(metrics["curv_refreshed"]) == 1.0

    half = lambda i: jax.tree.map(lambda x: x[2 * i:2 * i + 2], batch)
    g0 = jax.grad(model.loss_fn)(params, half(0))
    g1 = jax.grad(model.loss_fn)(params, half(1))
    want = jax.tree.map(
        lambda a, b: (a.astype(jnp.float32) ** 2
                      + b.astype(jnp.float32) ** 2) / 2, g0, g1)
    for h, w in zip(jax.tree.leaves(state.h), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(h), np.asarray(w),
                                   rtol=1e-5, atol=1e-7)
    # the Option-2 ridge from the same observations rode along
    assert all(float(x) > 0 for x in jax.tree.leaves(state.l))


def test_refresh_interval_gates_curvature():
    """refresh_every=2: steps 0 and 2 refresh, step 1 must leave H (and
    the stored ridge) untouched while still preconditioning."""
    cfg, model, params = _tiny()
    opt = make_optimizer("fednl", 1e-2, k_per_block=64, block=8)
    step = jax.jit(make_train_step(model, opt, refresh_every=2, n_silos=2))
    state = opt.init(params)
    flags, hs = [], []
    p = params
    for i in range(3):
        p, state, m = step(p, state, _batch(cfg, seed=i))
        flags.append(float(m["curv_refreshed"]))
        hs.append(jax.tree.leaves(state.h)[0])
        assert np.isfinite(float(m["loss"]))
    assert flags == [1.0, 0.0, 1.0]
    np.testing.assert_array_equal(np.asarray(hs[0]), np.asarray(hs[1]))
    assert float(jnp.max(jnp.abs(hs[2] - hs[1]))) > 0


def test_hvp_probe_path_trains():
    """Hutchinson curvature through the jvp-of-grad probe: finite loss,
    finite learned curvature, refresh engaged."""
    cfg, model, params = _tiny()
    opt = make_optimizer("fednl", 1e-3, k_per_block=64, block=8,
                         curvature="hutchinson")
    step = jax.jit(make_train_step(model, opt, refresh_every=1, n_silos=2,
                                   hvp=True))
    state = opt.init(params)
    p, state, m = step(params, state, _batch(cfg))
    assert float(m["curv_refreshed"]) == 1.0
    assert np.isfinite(float(m["loss"]))
    for h in jax.tree.leaves(state.h):
        assert bool(jnp.all(jnp.isfinite(h)))


def test_microbatch_accumulation_equivalence():
    """microbatches=4 must reproduce the monolithic step: same loss,
    same grad norm, same updated params (f32 reduction-order noise
    only). Smoke configs are f32, so tolerances are tight."""
    cfg, model, params = _tiny()
    batch = _batch(cfg, b=4)
    opt = make_optimizer("adamw", 1e-3)
    s1 = jax.jit(make_train_step(model, opt, microbatches=1))
    s4 = jax.jit(make_train_step(model, opt, microbatches=4))
    p1, _, m1 = s1(params, opt.init(params), batch)
    p4, _, m4 = s4(params, opt.init(params), batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m4["loss"]),
                               rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(float(m1["grad_norm"]),
                               float(m4["grad_norm"]), rtol=1e-4)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p4)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-6)


def test_first_order_path_unchanged():
    """The curvature phase must be invisible to first-order optimizers:
    a step built with refresh/silo args set produces bit-identical
    params to the plain one, and never reports a refresh."""
    cfg, model, params = _tiny()
    batch = _batch(cfg)
    opt = make_optimizer("adamw", 1e-3)
    plain = jax.jit(make_train_step(model, opt))
    gated = jax.jit(make_train_step(model, opt, refresh_every=8, n_silos=2))
    p_a, _, m_a = plain(params, opt.init(params), batch)
    p_b, _, m_b = gated(params, opt.init(params), batch)
    assert float(m_b["curv_refreshed"]) == 0.0
    for a, b in zip(jax.tree.leaves(p_a), jax.tree.leaves(p_b)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_uplink_bits_accounting():
    """Host-side refresh wire cost: positive, linear in n_silos, and a
    function of the 2D block partition — a (4, 3, 6) tensor costs the
    same as its (12, 6) collapse."""
    from repro.second_order import fednl_precond

    opt = make_optimizer("fednl", 1e-3, k_per_block=8, block=8)
    p3 = {"w": jnp.zeros((4, 3, 6))}
    p2 = {"w": jnp.zeros((12, 6))}
    one = opt.uplink_bits(p3)
    assert one > 0
    assert opt.uplink_bits(p3, n_silos=3) == 3 * one
    assert opt.uplink_bits(p2) == one
    # the adapter exposes the full second-order protocol
    adapter = fednl_precond(1e-3)
    assert adapter.observe and adapter.refresh and adapter.precondition


def test_opt_state_sharding_matches_params():
    """4 forced host devices (subprocess so the count doesn't leak):
    fednl curvature H and momentum carry the params' own NamedShardings;
    the step counter and per-tensor ridge scalars stay replicated."""
    code = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
        import jax, jax.numpy as jnp
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.launch.sharding import opt_state_shardings, tree_param_specs
        from repro.launch.steps import make_optimizer
        from repro.models import build_model

        cfg = get_config("qwen2-0.5b", smoke=True)
        model = build_model(cfg, use_remat=True)
        params = model.init_params(jax.random.PRNGKey(0))
        mesh = make_host_mesh()
        params = jax.device_put(params, tree_param_specs(params, mesh, cfg))
        n_sharded = sum(1 for p in jax.tree.leaves(params)
                        if not p.sharding.is_fully_replicated)
        assert n_sharded > 0, "nothing sharded on the 4-way mesh"
        opt = make_optimizer("fednl", 1e-3, k_per_block=64, block=8)
        shardings = opt_state_shardings(
            jax.eval_shape(opt.init, params), params, mesh, cfg)
        state = jax.jit(opt.init, out_shardings=shardings)(params)
        spec = lambda t: jax.tree.map(lambda x: x.sharding.spec, t)
        assert spec(state.h) == spec(params), (spec(state.h), spec(params))
        assert spec(state.mu) == spec(params)
        assert state.step.sharding.is_fully_replicated
        for x in jax.tree.leaves(state.l):
            assert x.sharding.is_fully_replicated
        print("OPT_SHARD_OK", n_sharded)
    """)
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "OPT_SHARD_OK" in out.stdout, out.stdout + out.stderr
