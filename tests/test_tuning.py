"""Kernel autotuner: cache keying/persistence, dispatch authority
(explicit argument > tuned winner > untuned default), measurement seam
determinism, and the VMEM-budget pricing of tuned configs."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.framework import Target, get_rule
from repro.kernels import VMEM_BUDGET_BYTES
from repro.kernels.scatter_accum import scatter_accumulate, scatter_accumulate_ref
from repro.kernels.tuning import (
    CACHE_ENV,
    KernelConfig,
    TuningCache,
    autotune_scatter_accumulate,
    bucket,
    cache_key,
    get_cache,
    lookup,
    record,
    scatter_candidates,
    set_cache,
)
from repro.kernels.tuning import analysis_targets as tuning_targets


@pytest.fixture(autouse=True)
def fresh_cache():
    """Each test runs against its own empty process-global cache; reset
    to lazy env-load afterwards so other test modules see a clean
    state."""
    set_cache(TuningCache())
    yield
    set_cache(None)


def _pairs(shape, k, n, seed=0):
    kv, ki = jax.random.split(jax.random.PRNGKey(seed))
    vals = jax.random.normal(kv, (n, k))
    idx = jax.random.randint(ki, (n, k), 0, shape[0] * shape[1])
    return vals, idx.astype(jnp.int32)


# -- cache keys ---------------------------------------------------------------


def test_bucket_next_pow2_min8():
    assert [bucket(x) for x in (1, 8, 9, 128, 300, 4096)] == \
        [8, 8, 16, 128, 512, 4096]


def test_cache_key_deterministic_and_bucketed():
    a = cache_key("scatter_accumulate", shape=(300, 300), k=64, n=4,
                  dtype=jnp.float32)
    b = cache_key("scatter_accumulate", shape=(500, 400), k=64, n=4,
                  dtype=jnp.float32)
    assert a == b  # both dims bucket to 512 — one entry serves nearby d
    assert a == cache_key("scatter_accumulate", shape=(300, 300), k=64,
                          n=4, dtype=jnp.float32)
    assert a != cache_key("scatter_accumulate", shape=(300, 300), k=65,
                          n=4, dtype=jnp.float32)
    assert a != cache_key("scatter_accumulate", shape=(300, 300), k=64,
                          n=4, dtype=jnp.float64)
    # every field is present in the flat string (the JSON cache is
    # greppable by construction)
    assert a.startswith("scatter_accumulate|d512x512|k64|n4|float32|")


def test_lookup_miss_returns_none():
    assert lookup("scatter_accumulate", shape=(64, 64), k=8, n=2,
                  dtype=jnp.float32) is None


def test_record_then_lookup_round_trip():
    cfg = KernelConfig(tile=(256, 512), chunk=256)
    record("scatter_accumulate", cfg, shape=(900, 900), k=128, n=8,
           dtype=jnp.float32)
    got = lookup("scatter_accumulate", shape=(1000, 600), k=128, n=8,
                 dtype=jnp.float32)  # same (1024, 1024) bucket
    assert got == cfg


# -- JSON persistence ---------------------------------------------------------


def test_cache_json_persistence_round_trip(tmp_path):
    c = TuningCache()
    k1 = cache_key("scatter_accumulate", shape=(512, 512), k=512, n=4,
                   dtype=jnp.float32)
    k2 = cache_key("hess_update", shape=(300, 123), dtype=jnp.bfloat16)
    k3 = cache_key("diff_topk_payload", shape=(512, 512), k=32, n=128,
                   dtype=jnp.float32)
    c.put(k1, KernelConfig(tile=(256, 512), chunk=1024))
    c.put(k2, KernelConfig(block=256))
    c.put(k3, KernelConfig(use_pallas=True))
    path = tmp_path / "cache.json"
    c.save(str(path))
    loaded = TuningCache.load(str(path))
    assert loaded.entries() == c.entries()
    # the persisted form is a plain {key: config} object + schema pin
    doc = json.loads(path.read_text())
    assert doc["schema"] == 1
    assert doc["configs"][k1] == {"tile": [256, 512], "chunk": 1024}


def test_cache_schema_mismatch_raises(tmp_path):
    path = tmp_path / "stale.json"
    path.write_text(json.dumps({"schema": 99, "configs": {}}))
    with pytest.raises(ValueError, match="schema"):
        TuningCache.load(str(path))


def test_env_pinned_cache_loads_lazily(tmp_path, monkeypatch):
    c = TuningCache()
    k1 = cache_key("scatter_accumulate", shape=(512, 512), k=512, n=4,
                   dtype=jnp.float32)
    c.put(k1, KernelConfig(tile=(512, 512), chunk=512))
    path = tmp_path / "ci_pin.json"
    c.save(str(path))
    monkeypatch.setenv(CACHE_ENV, str(path))
    set_cache(None)  # reset: next get_cache() performs the env load
    assert get_cache().get(k1) == KernelConfig(tile=(512, 512), chunk=512)


# -- dispatch authority -------------------------------------------------------


def _trace_str(fn, *args):
    return str(jax.make_jaxpr(fn)(*args))


def test_dispatch_honors_cached_tile():
    """An untuned call (no tile/chunk argument) must trace exactly like
    the explicit-config call once the cache holds a winner, and
    differently from the empty-cache default."""
    shape = (64, 256)
    vals, idx = _pairs(shape, k=32, n=3)

    # fresh lambda per trace: jit caches on the function object, and the
    # cache lookup lives in the plain wrapper the trace must re-run
    def untuned():
        return lambda v, i: scatter_accumulate(
            v, i, shape, use_pallas=True, interpret=True)

    explicit = lambda v, i: scatter_accumulate(
        v, i, shape, use_pallas=True, interpret=True, tile=(8, 128),
        chunk=256)
    base = _trace_str(untuned(), vals, idx)  # empty cache: single-block
    record("scatter_accumulate", KernelConfig(tile=(8, 128), chunk=256),
           shape=shape, k=32, n=3, dtype=vals.dtype)
    tuned = _trace_str(untuned(), vals, idx)
    assert tuned == _trace_str(explicit, vals, idx)
    assert tuned != base
    # and the tuned path's numerics are the reference's, exactly
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


def test_explicit_override_beats_cache():
    """The escape hatch: an explicit tile/chunk argument wins over a
    cached winner (the cache is consulted only when BOTH are None)."""
    shape = (64, 256)
    vals, idx = _pairs(shape, k=32, n=3)
    record("scatter_accumulate", KernelConfig(tile=(8, 128), chunk=256),
           shape=shape, k=32, n=3, dtype=vals.dtype)
    forced = lambda v, i: scatter_accumulate(
        v, i, shape, use_pallas=True, interpret=True, tile=(16, 128),
        chunk=512)
    reference = lambda v, i: scatter_accumulate(
        v, i, shape, use_pallas=True, interpret=True, tile=(16, 128),
        chunk=512)
    cached = lambda v, i: scatter_accumulate(
        v, i, shape, use_pallas=True, interpret=True)
    assert _trace_str(forced, vals, idx) == _trace_str(reference, vals, idx)
    assert _trace_str(forced, vals, idx) != _trace_str(cached, vals, idx)


def test_topk_dispatch_honors_cached_use_pallas():
    """use_pallas=None on the top-k family resolves through the cache:
    a recorded oracle winner must produce the oracle trace."""
    from repro.kernels.block_topk import block_topk_payload

    x = jax.random.normal(jax.random.PRNGKey(0), (256, 256))
    oracle = _trace_str(
        lambda m: block_topk_payload(m, k=16, block=128, use_pallas=False), x)
    record("block_topk_payload", KernelConfig(use_pallas=False),
           shape=x.shape, k=16, n=128, dtype=x.dtype)
    tuned = _trace_str(
        lambda m: block_topk_payload(m, k=16, block=128), x)
    assert tuned == oracle


# -- the measurement loop -----------------------------------------------------


def test_autotune_records_winner_deterministically():
    """With the deterministic timer seam the tuner must pick the same
    winner twice and leave it in the cache under the dispatch key."""
    shape = (64, 256)
    vals, idx = _pairs(shape, k=32, n=3)

    def stub_timer(fn):  # never executes the kernel: pure selection test
        stub_timer.calls += 1
        return float(stub_timer.calls)  # first measured candidate wins

    stub_timer.calls = 0
    w1 = autotune_scatter_accumulate(vals, idx, shape, use_pallas=True,
                                     interpret=True, timer=stub_timer)
    stub_timer.calls = 0
    w2 = autotune_scatter_accumulate(vals, idx, shape, use_pallas=True,
                                     interpret=True, timer=stub_timer,
                                     record_winner=False)
    assert w1 == w2
    assert lookup("scatter_accumulate", shape=shape, k=32, n=3,
                  dtype=vals.dtype) == w1


def test_autotune_winner_is_numerically_exact():
    """Whatever config the tuner lands on, the op's numerics must equal
    the untuned reference bit for bit (configs change scheduling, never
    values)."""
    shape = (64, 256)
    vals, idx = _pairs(shape, k=32, n=3, seed=5)
    autotune_scatter_accumulate(vals, idx, shape, use_pallas=True,
                                interpret=True,
                                timer=lambda fn: 1.0, max_measured=8)
    out = scatter_accumulate(vals, idx, shape, use_pallas=True,
                             interpret=True)
    ref = scatter_accumulate_ref(vals, idx, shape)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))


# -- VMEM-budget pricing ------------------------------------------------------


def _vmem_violations(jaxpr):
    rule = get_rule("vmem-budget")
    t = Target(name="test", kind="kernel", trace=lambda: None, rules=(),
               context={})
    return rule.check(jaxpr, t)


def test_candidates_fit_vmem_budget_when_traced():
    """Every candidate the generator emits must trace within the 8 MiB
    budget the vmem-budget analysis rule enforces — the tuner can never
    pick a config the analysis lane would reject."""
    shape, k, n = (4096, 4096), 2048, 4
    cands = scatter_candidates(shape, k, n, jnp.float32)
    assert cands, "candidate pool must not be empty"
    v = jax.ShapeDtypeStruct((n, k), jnp.float32)
    i = jax.ShapeDtypeStruct((n, k), jnp.int32)
    for cfg in cands:
        jaxpr = jax.make_jaxpr(lambda vv, ii, cfg=cfg: scatter_accumulate(
            vv, ii, shape, use_pallas=True, interpret=True,
            tile=cfg.tile, chunk=cfg.chunk or 512))(v, i)
        assert _vmem_violations(jaxpr) == [], f"config {cfg} over budget"


def test_single_block_candidate_gated_by_budget():
    """tile=None (whole accumulator in one VMEM block) is only offered
    while the padded accumulator fits the budget."""
    small = scatter_candidates((512, 512), 512, 4, jnp.float32)
    assert any(c.tile is None for c in small)
    big = scatter_candidates((8192, 8192), 2048, 4, jnp.float32)
    assert big and all(c.tile is not None for c in big)
    acc = 8192 * 8192 * 4
    assert acc > VMEM_BUDGET_BYTES  # the gate is real for this shape


def test_budget_guard_outranks_cache():
    """A (hand-pinned or stale) cache entry demanding the single-block
    kernel on an over-budget shape must still dispatch tiled — the
    budget guard wins over the tuner."""
    shape = (8192, 8192)
    record("scatter_accumulate", KernelConfig(tile=None, chunk=512),
           shape=shape, k=64, n=2, dtype=jnp.float32)
    v = jax.ShapeDtypeStruct((2, 64), jnp.float32)
    i = jax.ShapeDtypeStruct((2, 64), jnp.int32)
    jaxpr = jax.make_jaxpr(lambda vv, ii: scatter_accumulate(
        vv, ii, shape, use_pallas=True, interpret=True))(v, i)
    assert _vmem_violations(jaxpr) == []


# -- analysis integration -----------------------------------------------------


def test_tuning_analysis_targets_enumerate_cache():
    """Each cached winner becomes a traced analysis target priced by the
    vmem-budget rule; with an empty cache the defaults are traced."""
    empty = tuning_targets()
    assert empty and all("default" in t["name"] for t in empty)
    record("scatter_accumulate", KernelConfig(tile=(256, 512), chunk=512),
           shape=(4096, 4096), k=2048, n=4, dtype=jnp.float32)
    record("hess_update", KernelConfig(block=256), shape=(512, 512),
           dtype=jnp.float32)
    record("diff_topk_payload", KernelConfig(use_pallas=True),
           shape=(512, 512), k=32, n=128, dtype=jnp.float32)
    targets = tuning_targets()
    names = " ".join(t["name"] for t in targets)
    assert "tuned:" in names and len(targets) == 3
    for t in targets:
        jaxpr = t["trace"]()  # must trace cleanly...
        assert _vmem_violations(jaxpr) == []  # ...and price in budget


def test_analyze_sweep_includes_tuning_package():
    from repro.analysis.targets import analyze

    results = analyze(kinds=["kernel"], targets=["tuning"])
    assert results, "tuning package must contribute kernel targets"
    for t, violations in results:
        assert violations == [], f"{t.name}: {violations}"
