"""Wire-layer pins: bitstream codec round trips (bit-exact fp32/f64,
documented quantization bounds), -1 padding survival, per-silo encoding
of vmapped payload stacks, the traffic model, the unified ``WireReport``
cost API vs its deprecated aliases, and the ``seconds_per_round`` sweep
column."""


import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.experimental import enable_x64

from repro.core import (
    BlockTopK,
    DensePayload,
    DitheredPayload,
    Identity,
    LowRankPayload,
    NaturalSparsification,
    PowerSGD,
    RandK,
    RandomDithering,
    RankR,
    SparsePayload,
    TopK,
    payload_bits,
)
from repro.wire import (
    PRESETS,
    LinkModel,
    WireFormatError,
    WireReport,
    canonical,
    decode,
    encode,
    encode_silos,
    encoded_bytes,
    link_model,
    round_seconds,
    seconds_curve,
    silo_encoded_bytes,
    transfer_seconds,
    wire_cost,
)

D = 16


def _m(dtype=jnp.float32, d=D, seed=0):
    x = jax.random.normal(jax.random.PRNGKey(seed), (d, d), dtype)
    return 0.5 * (x + x.T)


def _families():
    return {
        "topk": TopK(k=3 * D),
        "randk": RandK(k=3 * D),
        "blocktopk": BlockTopK(k_per_block=4, block=8),
        "rankr": RankR(2),
        "powersgd": PowerSGD(r=2),
        "natural": NaturalSparsification(p=0.3),
        "identity": Identity(),
        "dithering": RandomDithering(s=4),
    }


def _bit_equal(a, b):
    """Array-for-array bitwise equality of two payload pytrees (-0.0 and
    +0.0 are DIFFERENT here — that is the point of the raw pin)."""
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if x.dtype != y.dtype or x.shape != y.shape:
            return False
        if x.tobytes() != y.tobytes():
            return False
    return True


# -- round trips: raw is bit-exact for every family -------------------------


@pytest.mark.parametrize("name", sorted(_families()))
def test_roundtrip_fp32_bit_exact(name):
    comp = _families()[name]
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    dec = decode(encode(p))
    assert _bit_equal(dec, canonical(p))
    # and the dense reconstruction is unchanged by canonicalization
    np.testing.assert_array_equal(
        np.asarray(comp.decompress(jax.tree_util.tree_map(jnp.asarray, dec),
                                   (D, D))),
        np.asarray(comp.decompress(p, (D, D))))


@pytest.mark.parametrize("name", sorted(_families()))
def test_roundtrip_f64_bit_exact(name):
    with enable_x64():
        comp = _families()[name]
        p = comp.compress(_m(jnp.float64), jax.random.PRNGKey(1))
        dec = decode(encode(p))
        assert _bit_equal(dec, canonical(p))


@pytest.mark.parametrize("name", sorted(_families()))
def test_roundtrip_unsorted_preserves_order(name):
    comp = _families()[name]
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    dec = decode(encode(p, sort_indices=False))
    host = jax.tree_util.tree_map(np.asarray, p)
    assert _bit_equal(dec, host)


def test_payload_encode_method_matches_module():
    comp = TopK(k=3 * D)
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    assert p.encode() == encode(p)
    assert comp.encode(p) == encode(p)
    assert _bit_equal(comp.decode(encode(p)), canonical(p))
    assert encoded_bytes(p) == len(encode(p))


# -- quantized value formats: documented bounds -----------------------------


def test_fp16_value_format_is_exact_cast():
    comp = TopK(k=3 * D)
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    dec = decode(encode(p, value_format="fp16"))
    want = np.asarray(canonical(p).values)
    got = np.asarray(dec.values)
    # decoded == orig.astype(f16).astype(f32), EXACTLY — and the index
    # stream is untouched by value quantization
    np.testing.assert_array_equal(got,
                                  want.astype(np.float16).astype(np.float32))
    np.testing.assert_array_equal(np.asarray(dec.indices),
                                  np.asarray(canonical(p).indices))


def test_int8_value_format_error_bound():
    comp = TopK(k=3 * D)
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    dec = decode(encode(p, value_format="int8"))
    want = np.asarray(canonical(p).values, np.float64)
    got = np.asarray(dec.values, np.float64)
    bound = np.max(np.abs(want)) / 250.0  # documented: <= max|v| / 250
    assert np.max(np.abs(got - want)) <= bound


def test_quantized_formats_shrink_the_buffer():
    comp = TopK(k=3 * D)
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    raw, f16, i8 = (len(encode(p, value_format=f))
                    for f in ("raw", "fp16", "int8"))
    assert i8 < f16 < raw


def test_dithered_bit_exact_under_every_value_format():
    """Dithered payloads are categorical — quantizing the (already
    integer) level stream would be a bug; all three formats round-trip
    bit-exactly."""
    comp = RandomDithering(s=4)
    p = comp.compress(_m(jnp.float32), jax.random.PRNGKey(1))
    for fmt in ("raw", "fp16", "int8"):
        assert _bit_equal(decode(encode(p, value_format=fmt)), canonical(p))


# -- padding, signed zero, malformed buffers --------------------------------


def test_minus_one_padding_survives():
    p = SparsePayload(values=jnp.array([1.5, -2.0, 0.0, 0.0], jnp.float32),
                      indices=jnp.array([7, 3, -1, -1], jnp.int32),
                      universe=D * D)
    dec = decode(encode(p))
    can = canonical(p)
    assert _bit_equal(dec, can)
    assert np.sum(np.asarray(dec.indices) == -1) == 2
    # padding slots are dropped by decompress on both sides
    comp = TopK(k=4)
    np.testing.assert_array_equal(
        np.asarray(comp.decompress(jax.tree_util.tree_map(jnp.asarray, dec),
                                   (D, D))),
        np.asarray(comp.decompress(p, (D, D))))


def test_negative_zero_survives_indexed_dense():
    p = DensePayload(values=jnp.array([[0.0, -0.0], [3.0, 0.0]], jnp.float32),
                     count=1, indexed=True, universe=4)
    dec = decode(encode(p))
    got = np.asarray(dec.values)
    assert got[0, 1] == 0.0 and np.signbit(got[0, 1])  # -0.0 kept
    assert not np.signbit(got[0, 0])
    assert _bit_equal(dec, canonical(p))


def test_decode_rejects_garbage_and_wrong_shape():
    with pytest.raises(WireFormatError):
        decode(b"\x00\x01\x02\x03")
    comp = Identity()
    buf = encode(comp.compress(_m(jnp.float32)))
    with pytest.raises(WireFormatError):
        decode(buf, shape=(D + 1, D + 1))
    with pytest.raises(WireFormatError):
        encode(comp.compress(_m(jnp.float32)), value_format="fp8")


def test_stacked_payload_must_use_encode_silos():
    comp = TopK(k=3 * D)
    diffs = jax.random.normal(jax.random.PRNGKey(0), (4, D, D))
    stack = jax.vmap(comp.compress)(diffs)
    with pytest.raises(WireFormatError, match="encode_silos"):
        encode(stack)


def test_encode_silos_per_silo_buffers():
    """A vmapped-over-silos stack (the engine's uplink unit) encodes to
    one buffer per silo, each decoding to that silo's canonical slice.
    ``encode_silos`` is a LAZY generator (cross-device cohorts encode
    10k+ buffers — they must stream, not materialize)."""
    import types

    n = 4
    comp = TopK(k=3 * D)
    diffs = jax.random.normal(jax.random.PRNGKey(0), (n, D, D))
    stack = jax.vmap(comp.compress)(diffs)
    gen = encode_silos(stack)
    assert isinstance(gen, types.GeneratorType)
    bufs = list(gen)
    assert len(bufs) == n
    for i, buf in enumerate(bufs):
        single = comp.compress(diffs[i])
        assert _bit_equal(decode(buf), canonical(single))
    sizes = silo_encoded_bytes(stack)
    assert sizes.shape == (n,) and all(sizes == [len(b) for b in bufs])


# -- the honest bits() signature --------------------------------------------


def test_bits_rejects_unknown_index_coding():
    p = TopK(k=4).compress(_m(jnp.float32))
    with pytest.raises(ValueError, match="index_coding"):
        p.bits(index_coding="huffman")


def test_index_coding_noop_families_documented():
    """LowRank and Dithered payloads carry no index stream: the entropy
    coding is a no-op (raw == entropy), by the one documented rule on
    the Payload base class rather than silently-ignored kwargs."""
    lr = RankR(2).compress(_m(jnp.float32))
    di = RandomDithering(s=4).compress(_m(jnp.float32), jax.random.PRNGKey(1))
    assert isinstance(lr, LowRankPayload)
    assert isinstance(di, DitheredPayload)
    for p in (lr, di):
        assert p.bits() == p.bits(index_coding="entropy")
    # indexed families genuinely differ
    sp = TopK(k=3 * D).compress(_m(jnp.float32))
    assert sp.bits(index_coding="entropy") < sp.bits()


# -- WireReport: the unified cost surface vs the deprecated quartet ---------


def test_wire_cost_matches_deprecated_aliases():
    comp = TopK(k=3 * D)
    rep = wire_cost(comp, (D, D), dtype=jnp.float32)
    assert isinstance(rep, WireReport)
    assert rep.analytic_bits == comp.bits((D, D)) == comp.spec((D, D)).bits
    assert rep.raw_bits == payload_bits(comp, (D, D), dtype=jnp.float32)
    assert rep.entropy_bits == payload_bits(comp, (D, D), dtype=jnp.float32,
                                            index_coding="entropy")
    p = comp.compress(jax.random.normal(jax.random.PRNGKey(0), (D, D),
                                        jnp.float32), jax.random.PRNGKey(1))
    assert rep.encoded_bytes == len(encode(p))
    assert rep.encoded_bits == 8 * rep.encoded_bytes
    assert rep.entropy_bits <= rep.raw_bits
    assert rep.seconds("wan", n=4) > 0.0


def test_wire_cost_lazy_core_reexport():
    import repro.core as core

    assert core.wire_cost is wire_cost
    assert core.WireReport is WireReport
    with pytest.raises(AttributeError):
        core.not_a_wire_name


# -- traffic model ----------------------------------------------------------


def test_traffic_deterministic_and_monotone():
    bits = 8.0 * 1e6
    a = round_seconds(bits, "wan", n=8, seed=3)
    assert a == round_seconds(bits, "wan", n=8, seed=3)  # deterministic
    assert round_seconds(2 * bits, "wan", n=8, seed=3) > a  # more bits
    # straggler max dominates the mean
    assert a >= round_seconds(bits, "wan", n=8, seed=3, reduce="mean")
    with pytest.raises(ValueError):
        round_seconds(bits, "wan", reduce="median")


def test_traffic_presets_ordered():
    bits = 8.0 * 1e6
    t = {name: round_seconds(bits, name, n=8) for name in PRESETS}
    assert t["datacenter"] < t["wan"] < t["fl-cross-device"]
    with pytest.raises(ValueError, match="unknown link preset"):
        link_model("dialup")
    assert link_model(None) is None
    custom = LinkModel("lab", bandwidth_bps=1e9, latency_s=0.001)
    assert link_model(custom) is custom
    # sigma=0 link: exact closed form
    assert round_seconds(1e9, custom, n=4) == pytest.approx(1.001)


def test_traffic_curves_and_bytes():
    curve = seconds_curve(1e6, "wan", n=4, num_rounds=5, init_bits=2e6)
    assert curve.shape == (6,)
    assert np.all(np.diff(curve) > 0)
    assert curve[0] > 0  # the init ship is charged up front
    assert transfer_seconds(125000, "datacenter") == \
        round_seconds(1e6, "datacenter")


def test_mean_corrected_bandwidth_spread():
    link = PRESETS["fl-cross-device"]
    bw = link.silo_bandwidths(20000, seed=0)
    assert np.all(bw > 0)
    assert abs(np.mean(bw) / link.bandwidth_bps - 1.0) < 0.05


# -- sweep integration: the seconds_per_round column ------------------------


@pytest.mark.slow
def test_sweep_records_seconds_per_round():
    from repro.core.objectives import batch_grad, batch_hess, global_value
    from repro.data.synthetic import make_synthetic
    from repro.engine import ExperimentSpec, Sweep

    with enable_x64():
        data = make_synthetic(jax.random.PRNGKey(0), alpha=0.5, beta=0.5,
                              n=4, m=24, d=8, lam=1e-3)
        problem = dict(grad=lambda x: batch_grad(x, data),
                       hess=lambda x: batch_hess(x, data),
                       val=lambda x: global_value(x, data), n=4, d=8,
                       fstar=0.0)
        spec = ExperimentSpec("fednl", "topk", 16, num_rounds=3)
        res = Sweep([spec]).run(problem, x0=jnp.zeros(8))  # link="wan"
        cell = res.cells[0]
        assert cell.seconds_per_round is not None
        assert np.isfinite(cell.seconds_per_round)
        assert cell.seconds_per_round > 0
        rows = res.records()
        assert all(r["seconds_per_round"] == cell.seconds_per_round
                   for r in rows)
        assert res.summary()[0]["seconds_per_round"] == cell.seconds_per_round
        # pricing is the traffic model on the measured wire bits
        from repro.engine import measured_bits_per_round, seconds_per_round
        method = spec.build(__import__("repro.engine.method",
                                       fromlist=["Oracles"]).Oracles(
            value=problem["val"], grad=problem["grad"], hess=problem["hess"]))
        want = round_seconds(measured_bits_per_round(method, 8), "wan", n=4)
        assert cell.seconds_per_round == want
        assert seconds_per_round(method, 8, 4) == want
        # link=None switches the model off
        res2 = Sweep([spec], link=None).run(problem, x0=jnp.zeros(8))
        assert res2.cells[0].seconds_per_round is None
        assert np.isnan(res2.records()[0]["seconds_per_round"])
